"""Serve SPARQL against a live, mutating triple store.

Demonstrates the epoch-snapshot consistency contract of
``repro.serve.triple_store`` (docs/serving.md): a standing store admits
interleaved add/delete batches and SPARQL queries; every answer is computed
against the fixpoint of a *completed* maintenance epoch and expanded through
that epoch's rho — even when the query lands between an overdelete wave and
its rederivation.

Run: PYTHONPATH=src python examples/serve_sparql.py
"""

import numpy as np

from repro.data.generator import generate, sample_update_stream
from repro.serve.triple_store import TripleStore
from repro.sparql.algebra import Query


def main() -> None:
    facts, program, dic = generate(
        n_groups=4, group_size=4, n_spokes_per=3, n_plain=60,
        hierarchy_depth=2, seed=0,
    )
    print(f"explicit facts: {facts.shape[0]}")
    store = TripleStore(facts, program, dic)
    print(
        f"epoch {store.epoch}: serving {store.snapshot.triples.shape[0]} "
        "normal-form triples"
    )

    # Q: who points a :spoke at group 0's entity?  ?y is projected out, so
    # each answer is multiplied by the sameAs-clique size bound to ?y.
    spoke = dic.id_of(":spoke")
    q = Query([(-1, spoke, -2)], [], [-1], False)
    t = store.query_now(q)
    print(f"\n[epoch {t.epoch}] spoke subjects (bag): {sorted(t.answer.items())[:4]} ...")

    # delete one :idProp edge -> the derived clique splits; admit a query
    # while the maintenance epoch is mid-overdelete
    idp = dic.id_of(":idProp")
    edge = facts[np.flatnonzero(facts[:, 1] == idp)[:1]]
    ut = store.submit_update("delete", edge)
    while store.inflight_phase != "overdeleted":
        store.step()
    mid = store.submit_query(q)
    store.step()  # answers the query (previous epoch), advances maintenance
    print(
        f"\nquery admitted mid-overdelete: served at epoch {mid.epoch} "
        f"(update still {ut.status}); bag total {sum(mid.answer.values())}"
    )
    store.drain()
    after = store.query_now(q)
    print(
        f"after the barrier: epoch {after.epoch}, bag total "
        f"{sum(after.answer.values())} (clique split shrank the multiplicities)"
    )

    # a mixed query+update trace through the scheduler
    trace = sample_update_stream(
        facts, dic, n_events=8, batch=12, p_query=0.5, seed=1
    )
    tickets = []
    for op, payload in trace:
        if op == "query":
            tickets.append(store.submit_query(payload))
        else:
            store.submit_update(op, payload)
        store.step()
    store.drain()
    print("\nmixed trace: queries answered at epochs "
          f"{[t.epoch for t in tickets]} (final epoch {store.epoch})")


if __name__ == "__main__":
    main()
