"""End-to-end driver: materialise a KG and answer SPARQL over it.

This is the paper-kind equivalent of "train a model end-to-end": generate a
clique-injected KG (default: the OpenCyc-like equality-dense profile), run
the full REW materialisation, validate Theorem 1 against the AX oracle, then
answer SPARQL queries on the succinct store with bag-correct multiplicities.

Run:  PYTHONPATH=src python examples/materialise_kg.py [--profile claros_like]
      PYTHONPATH=src python examples/materialise_kg.py --spmd 4   # 4-shard SPMD
"""

import argparse
import time

from repro.core.materialise import check_theorem1, materialise
from repro.data.generator import PROFILES, generate
from repro.sparql import Query, evaluate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="opencyc_like", choices=sorted(PROFILES))
    ap.add_argument("--spmd", type=int, default=0,
                    help="run the JAX SPMD engine over N fake devices instead")
    ap.add_argument("--skip-ax", action="store_true")
    args = ap.parse_args()

    facts, program, dic = generate(**PROFILES[args.profile])
    print(f"[{args.profile}] {facts.shape[0]} facts, {len(program)} rules, "
          f"{dic.n_resources} resources")

    if args.spmd:
        from repro.core.engine_jax import JaxEngine
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((args.spmd,), ("data",))
        eng = JaxEngine(dic.n_resources, capacity=(1 << 17) // args.spmd,
                        bind_cap=1 << 14, out_cap=1 << 14, rewrite_cap=1 << 14,
                        mesh=mesh)
        t0 = time.time()
        spo, rep, stats = eng.materialise(facts, program)
        print(f"SPMD({args.spmd} shards): {spo.shape[0]} triples, "
              f"{stats.derivations} derivations, {time.time()-t0:.2f}s")
        return

    t0 = time.time()
    rew = materialise(facts, program, dic.n_resources, mode="REW")
    t_rew = time.time() - t0
    print(f"REW: {rew.stats.triples_unmarked} triples, "
          f"{rew.stats.derivations} derivations, "
          f"{rew.stats.merged_resources} merged, {t_rew:.2f}s")

    if not args.skip_ax:
        t0 = time.time()
        ax = materialise(facts, program, dic.n_resources, mode="AX")
        t_ax = time.time() - t0
        print(f"AX : {ax.stats.triples_unmarked} triples, "
              f"{ax.stats.derivations} derivations, {t_ax:.2f}s "
              f"-> REW is {t_ax / max(t_rew, 1e-9):.1f}x faster")
        check_theorem1(rew, ax)
        print("Theorem 1 validated (T^rho == AX materialisation)")

    q = Query.parse("SELECT ?x WHERE { (?x, :spoke, ?y) }", dic)
    t0 = time.time()
    ans = evaluate(q, rew.triples(), rew.rep, dic)
    print(f"\nSPARQL over T (bag semantics): {sum(ans.values())} answers, "
          f"{len(ans)} distinct, {1e3*(time.time()-t0):.1f}ms")
    top = ans.most_common(3)
    for row, count in top:
        print(f"   {row} x{count}")


if __name__ == "__main__":
    main()
