"""Train an assigned-architecture LM with the fault-tolerant trainer.

Default runs the REDUCED smollm config for 300 steps on CPU (checkpointing
every 50; kill it mid-run and re-invoke — it resumes from the newest
checkpoint with identical losses).  ``--arch`` selects any assigned LM
config; ``--full`` uses the full (paper-exact) config, which is what the
dry-run lowers on the production mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

import jax

from repro.configs import get_arch
from repro.data.pipeline import lm_batch
from repro.models import transformer as lm
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.config if args.full else spec.reduced
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(v.size) for v in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch {args.batch} x seq {args.seq}, {args.steps} steps")

    def loss_fn(p, batch):
        return lm.loss_fn(p, cfg, batch["tokens"], batch["labels"])

    def batch_fn(step):
        return lm_batch(step, args.batch, args.seq, cfg.vocab)

    trainer = Trainer(
        loss_fn, params, batch_fn,
        TrainConfig(
            n_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
            lr=1e-3, log_every=20,
            heartbeat_path=f"{args.ckpt_dir}/heartbeat",
        ),
    )
    if trainer.resume():
        print(f"resumed from checkpoint at step {trainer.step}")
    losses = trainer.run()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    trainer.close()


if __name__ == "__main__":
    main()
