"""Serve a small LM with continuously-batched requests.

Submits a burst of prompts against a 4-slot KV arena: the engine prefills
into free slots, decodes all active slots in one fused step per tick, and
back-fills slots as sequences finish (see serve/engine.py).

Run:  PYTHONPATH=src python examples/serve_lm.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as lm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, n_slots=args.slots, max_len=64, eos_id=-1)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        prompt = rng.integers(2, cfg.vocab, plen).tolist()
        eng.submit(Request(uid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
