"""Quickstart: the paper's running example (§3/§4, Table 1).

Materialises P_ex = {(R), (S), F1..F3} about :Obama / :USPresident /
:USA / :US / :America with explicit owl:sameAs axiomatisation (AX) and with
rewriting (REW), and prints the numbers the paper quotes: >60 derivations
under AX vs 6 under REW, a 3-triple final store, and the representative map.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.materialise import check_theorem1, expand, materialise
from repro.data.datasets import pex


def name_triples(triples, dic):
    return sorted(
        f"<{dic.lookup(s)}, {dic.lookup(p)}, {dic.lookup(o)}>" for s, p, o in triples
    )


def main():
    facts, program, dic = pex()
    print("Input facts:")
    for row in name_triples(facts, dic):
        print("  ", row)

    ax = materialise(facts, program, dic.n_resources, mode="AX")
    rew = materialise(facts, program, dic.n_resources, mode="REW")
    check_theorem1(rew, ax)  # Theorem 1 (1)-(3) + expansion == AX

    print("\nAX  (explicit ~=1..~=5 axiomatisation):")
    print(f"   triples: {ax.stats.triples_unmarked}")
    print(f"   derivations: {ax.stats.derivations}   (paper: >60 for sameAs alone)")

    print("\nREW (the paper's rewriting algorithm):")
    print(f"   triples (unmarked): {rew.stats.triples_unmarked}")
    print(f"   derivations: {rew.stats.derivations}   (paper: 6)")
    print(f"   merged resources: {rew.stats.merged_resources}")
    print("   final store:")
    for row in name_triples(rew.triples(), dic):
        print("     ", row)

    print("\nRepresentative map (non-identity):")
    for rid in range(dic.n_resources):
        rep = int(rew.rep[rid])
        if rep != rid:
            print(f"   rho({dic.lookup(rid)}) = {dic.lookup(rep)}")

    exp = expand(rew.triples(), rew.rep)
    ax_set = {tuple(t) for t in ax.triples()}
    print(f"\nTheorem 1(3): |T^rho| = {len(exp)} == |AX| = {len(ax_set)}:",
          exp == ax_set)


if __name__ == "__main__":
    main()
