"""owl:sameAs rewriting as entity resolution for GNN training.

The framework-level integration of the paper's technique with the assigned
GNN architectures (DESIGN.md §4): a KG whose entities carry duplicate
registrations is materialised with REW; the representative map rho then
rewrites the GNN's edge_index (the ``rewrite_triples`` kernel's op) and
merged nodes collapse — fewer nodes and deduplicated edges before message
passing.  The same GatedGCN trains on both graphs; the deduped one is
smaller and converges on the task the duplicates used to fragment.

Run:  PYTHONPATH=src python examples/kg_dedup_gnn.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.materialise import materialise
from repro.data.generator import generate
from repro.models.gnn import gatedgcn
from repro.optim import adamw_init, adamw_update


def build_graph_from_kg(triples, n_nodes, d_feat, rng):
    """Edge list = non-sameAs payload triples; random features per node."""
    from repro.core.terms import SAME_AS

    payload = triples[triples[:, 1] != SAME_AS]
    src, dst = payload[:, 0], payload[:, 2]
    x = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = (np.arange(n_nodes) % 4).astype(np.int32)
    return {
        "x": x,
        "edge_index": np.stack([src, dst]).astype(np.int32),
        "edge_attr": np.ones((src.shape[0], 1), np.float32),
        "labels": labels,
        "train_mask": np.ones(n_nodes, np.float32),
    }


def train(batch, steps=40):
    cfg = get_arch("gatedgcn").reduced
    import dataclasses

    cfg = dataclasses.replace(cfg, d_in=batch["x"].shape[1])
    params = gatedgcn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(gatedgcn.loss_fn)(params, cfg, batch)
        params, opt, _ = adamw_update(params, grads, opt, lr=3e-3)
        return params, opt, loss

    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    t0 = time.time()
    for _ in range(steps):
        params, opt, loss = step(params, opt, batch)
    return float(loss), time.time() - t0


def main():
    rng = np.random.default_rng(0)
    facts, program, dic = generate(
        n_groups=150, group_size=5, n_spokes_per=4, n_plain=4000, hierarchy_depth=0
    )
    res = materialise(facts, program, dic.n_resources, mode="REW")
    rep = np.asarray(res.rep)

    # RAW graph: duplicates present (edges point at different copies)
    raw = build_graph_from_kg(facts, dic.n_resources, d_feat=16, rng=rng)

    # DEDUP graph: rewrite edge endpoints through rho, drop duplicate edges
    from repro.kernels import ops

    spo = np.stack(
        [raw["edge_index"][0], np.zeros_like(raw["edge_index"][0]), raw["edge_index"][1]],
        axis=1,
    )
    rewritten, _changed = ops.rewrite_triples(spo, rep, interpret=True)
    rewritten = np.asarray(rewritten)
    edges = np.unique(rewritten[:, [0, 2]], axis=0)
    dedup = dict(raw)
    dedup["edge_index"] = edges.T.astype(np.int32).copy()
    dedup["edge_attr"] = np.ones((edges.shape[0], 1), np.float32)

    n_merged = int((rep != np.arange(rep.shape[0])).sum())
    print(f"KG: {facts.shape[0]} facts, {n_merged} entities merged by rho")
    print(f"raw graph:   {raw['edge_index'].shape[1]} edges")
    print(f"dedup graph: {dedup['edge_index'].shape[1]} edges "
          f"({raw['edge_index'].shape[1] - dedup['edge_index'].shape[1]} removed)")

    loss_raw, t_raw = train(raw)
    loss_dd, t_dd = train(dedup)
    print(f"gatedgcn 40 steps | raw:   loss={loss_raw:.3f}  {t_raw:.1f}s")
    print(f"gatedgcn 40 steps | dedup: loss={loss_dd:.3f}  {t_dd:.1f}s")


if __name__ == "__main__":
    main()
