"""int8 error-feedback gradient compression: quantiser invariants,
error-feedback accumulation, convergence parity, and the shard_map pod
exchange."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.optim.compression import (
    compress_with_feedback,
    compressed_grad_exchange,
    dequantize_int8,
    init_residuals,
    quantize_int8,
    wire_bytes,
)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1, max_size=64))
def test_quantize_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ulp of the int8 grid


def test_error_feedback_recovers_bias():
    """A constant small gradient must not be lost: with error feedback the
    AVERAGE dequantised update converges to the true gradient."""
    g = jnp.full((32,), 1e-4, jnp.float32)  # tiny vs a 1.0 outlier
    g = g.at[0].set(1.0)
    e = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    n = 64
    for _ in range(n):
        (q, s), e = compress_with_feedback(g, e)
        total = total + dequantize_int8(q, s)
    # error-feedback bound: |avg - g| <= grid/(2n) = (1/127)/(2*64) ~ 6e-5
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g), atol=1.5e-4)


def test_wire_bytes_4x():
    params = {"a": jnp.zeros((128, 128)), "b": jnp.zeros((77,))}
    comp, full = wire_bytes(params)
    assert full / comp > 3.9


def test_shardmap_pod_exchange():
    """2 fake pods exchange compressed grads; mean matches f32 all-reduce."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under forced host device count)")
    mesh = make_mesh((2,), ("pod",))
    from jax.sharding import PartitionSpec as P

    g_pods = jnp.stack(
        [jnp.linspace(-1, 1, 64), jnp.linspace(0, 2, 64)]
    ).astype(jnp.float32)  # (2, 64): one grad per pod
    e_pods = jnp.zeros_like(g_pods)

    def body(g, e):
        mean, new_e = compressed_grad_exchange({"g": g[0]}, {"g": e[0]}, axis="pod")
        return mean["g"][None], new_e["g"][None]

    out, new_e = jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod")),
        )
    )(g_pods, e_pods)
    expect = np.asarray(g_pods).mean(0)
    np.testing.assert_allclose(np.asarray(out)[0], expect, atol=1e-2)
    np.testing.assert_allclose(np.asarray(out)[1], expect, atol=1e-2)


def test_sgd_convergence_parity():
    """SGD on a quadratic with compressed grads converges like exact SGD."""
    w_true = jnp.asarray(np.random.default_rng(0).normal(size=16), jnp.float32)

    def grad_fn(w):
        return w - w_true

    w_exact = jnp.zeros(16)
    w_comp = jnp.zeros(16)
    e = jnp.zeros(16)
    lr = 0.2
    for _ in range(80):
        w_exact = w_exact - lr * grad_fn(w_exact)
        (q, s), e = compress_with_feedback(grad_fn(w_comp), e)
        w_comp = w_comp - lr * dequantize_int8(q, s)
    assert float(jnp.linalg.norm(w_exact - w_true)) < 1e-3
    assert float(jnp.linalg.norm(w_comp - w_true)) < 1e-2
