"""Sharded incremental maintenance (engine path) vs the from-scratch oracle.

In-process tests run the device path single-device (the same code the mesh
wraps with shard_map); the mesh-parametrised equivalence tests run in a
subprocess with 4 fake CPU devices (``XLA_FLAGS`` must be set before the
first jax import — the pattern of tests/test_distributed.py) and assert
device-count invariance of the final store across 1/2/4 shards plus the
owner-routed exchange variant.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine_jax import CapacityError, JaxEngine, _pow2
from repro.core.materialise import materialise_rew
from repro.core.rules import parse_program
from repro.core.terms import Dictionary
from repro.core.triples import apply_op as _apply, pack
from repro.data.datasets import clique_with_spokes, pex, single_clique
from repro.data.generator import generate, sample_update_stream


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


def _engine(dic, cap=1 << 10, **kw):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap, **kw,
    )


def _assert_state_matches_scratch(eng, state, explicit, program, n_resources):
    ref = materialise_rew(explicit, program, n_resources)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())
    rep = eng.state_rep(state)
    assert (rep[: ref.rep.shape[0]] == ref.rep).all()
    tail = rep[ref.rep.shape[0] :]
    assert (tail == np.arange(ref.rep.shape[0], rep.shape[0])).all()


# ---------------------------------------------------------------------------
# single-device engine path (in-process)
# ---------------------------------------------------------------------------

def test_engine_add_matches_scratch():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts[:1], prog)
    eng.add_facts(state, facts[1:])
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_add_new_resources_grows_rep():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    new_id = dic.n_resources + 5
    delta = np.asarray([[new_id, facts[0, 1], facts[0, 2]]], np.int32)
    eng.add_facts(state, delta)
    all_facts = np.concatenate([facts, delta], axis=0)
    ref = materialise_rew(all_facts, prog, new_id + 1)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())
    assert (eng.state_rep(state) == ref.rep[: state.n_res]).all()


def test_engine_delete_splits_clique():
    facts, prog, dic = single_clique(6)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts[2:3])  # a2 ~ a3: {a0..a2} | {a3..a5}
    remaining = np.concatenate([facts[:2], facts[3:]], axis=0)
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)
    reps = np.unique(eng.state_rep(state)[np.unique(facts[:, [0, 2]])])
    assert reps.shape[0] == 2
    assert state.stats.suspects_split >= 1
    assert state.stats.overdeleted > 0


def test_engine_delete_derived_sameas_support():
    """Deleting :idProp edges must split the rule-derived clique on-device."""
    facts, prog, dic = generate(
        n_groups=3, group_size=4, n_spokes_per=2, n_plain=30, hierarchy_depth=2
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    idp = dic.id_of(":idProp")
    id_rows = np.flatnonzero(facts[:, 1] == idp)
    delta = facts[id_rows[:2]]
    eng.delete_facts(state, delta)
    remaining = facts[~np.isin(pack(facts), pack(delta))]
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)


def test_engine_update_stream_matches_scratch():
    facts, prog, dic = generate(
        n_groups=3, group_size=3, n_spokes_per=2, n_plain=40,
        hierarchy_depth=2, seed=0,
    )
    events = sample_update_stream(facts, dic, n_events=5, batch=10, seed=0)
    eng = _engine(dic, cap=1 << 11)
    state = eng.materialise_state(facts, prog)
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        _assert_state_matches_scratch(eng, state, explicit, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# targeted rederivation (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------

def test_targeted_rederive_restores_alternative_derivation():
    """The counter/trace acceptance test: a fact with an alternative
    derivation from the surviving store is restored WITHOUT any
    unconstrained full-rule evaluation — the rederive join is head-bound,
    its width a small constant rather than the arena capacity."""
    dic = Dictionary()
    prog = parse_program([
        "(?x, :p, ?y) <- (?x, :q, ?y)",
        "(?x, :p, ?y) <- (?x, :r, ?y)",
    ], dic)
    q, r_ = dic.id_of(":q"), dic.id_of(":r")
    a, b = dic.intern(":a"), dic.intern(":b")
    facts = np.asarray([[a, q, b], [a, r_, b]], np.int32)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    full_before = state.stats.full_plan_evals
    eng.delete_facts(state, facts[:1])
    _assert_state_matches_scratch(eng, state, facts[1:], prog, dic.n_resources)
    st = state.stats
    assert st.rederive_targeted >= 1
    assert st.rederive_full_fallback == 0
    # no rule was evaluated unconstrained against the surviving arena —
    # neither by the delete-side rederivation nor by a rho-change requeue
    assert st.full_plan_evals == full_before
    # the head-bound seed table is bounded by the overdelete delta (plus
    # the 64-row compile-width floor), never by the arena capacity
    assert 0 < st.rederive_join_width <= max(64, _pow2(st.overdeleted))
    assert st.rederive_join_width < eng.capacity


def test_targeted_rederive_join_width_bounded_on_clique_split():
    """Store-scale clique-split deletes (the uobm regression shape) keep
    the rederive joins instance-bound: no whole-rule fallback, seed width
    bounded by the overdelete cardinality."""
    facts, prog, dic = generate(
        n_groups=2, group_size=4, n_spokes_per=2, n_plain=30,
        hierarchy_depth=2, seed=1,
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    idp = dic.id_of(":idProp")
    delta = facts[np.flatnonzero(facts[:, 1] == idp)[:2]]
    eng.delete_facts(state, delta)
    remaining = facts[~np.isin(pack(facts), pack(delta))]
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)
    st = state.stats
    assert st.overdeleted > 0
    assert st.rederive_full_fallback == 0
    assert st.rederive_join_width <= max(64, _pow2(st.overdeleted))
    assert st.rederive_join_width < eng.capacity


def test_const_head_rule_falls_back_to_whole_rule_requeue():
    """A variable-free head admits no instance constraint: the documented
    whole-rule fallback fires, and the fact is still restored."""
    dic = Dictionary()
    prog = parse_program([
        "(:marker, :flag, :on) <- (?x, :q, ?y)",
    ], dic)
    q = dic.id_of(":q")
    a, b, c, d = (dic.intern(t) for t in (":a", ":b", ":c", ":d"))
    facts = np.asarray([[a, q, b], [c, q, d]], np.int32)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts[:1])
    _assert_state_matches_scratch(eng, state, facts[1:], prog, dic.n_resources)
    assert state.stats.rederive_full_fallback == 1
    assert state.stats.rederive_targeted == 0


def test_split_with_member_constant_head_restores_fact():
    """The pre-/post-split corner of ISSUE 5 satellite 2, end to end: a rule
    head constant that is a non-representative MEMBER of a clique which
    splits.  Overdelete masks (and the extracted tombstone rows) hold
    PRE-split normal forms — the head constant rewrote to the old clique
    representative — while the rule is rewritten under the POST-split rho,
    where the constant reverted to itself.  Matching naively in post-split
    space would find no overdeleted instance, skip the rule, and lose the
    restorable fact; the rep_old-collapsed matching restores it."""
    dic = Dictionary()
    a = dic.intern_many([f":a{i}" for i in range(4)])  # before the rules!
    prog = parse_program([
        "(?x, owl:sameAs, ?y) <- (?x, :idProp, ?v) & (?y, :idProp, ?v)",
        "(?x, :flag, :a2) <- (?x, :q, ?y)",
    ], dic)
    idp, qq = dic.id_of(":idProp"), dic.id_of(":q")
    v, s, t = dic.intern(":v"), dic.intern(":s"), dic.intern(":t")
    facts = np.asarray(
        [[ai, idp, v] for ai in a] + [[s, qq, t]], np.int32
    )
    assert a[2] != min(a)  # :a2 must NOT be the pre-split representative
    eng = _engine(dic, cap=512)
    state = eng.materialise_state(facts, prog)
    # pre-delete, the flag fact is stored under the clique representative
    pre = eng.state_triples(state)
    flag = dic.id_of(":flag")
    assert [s, flag, min(a)] in pre.tolist()
    # deleting a2's idProp edge splits the clique: {a0, a1, a3} re-merge,
    # a2 reverts to a singleton — and (s, :flag, a2) must be rederived
    edge = np.asarray([[a[2], idp, v]], np.int32)
    eng.delete_facts(state, edge)
    remaining = facts[~np.isin(pack(facts), pack(edge))]
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)
    post = eng.state_triples(state).tolist()
    assert [s, flag, a[2]] in post
    assert state.stats.rederive_targeted >= 1


# ---------------------------------------------------------------------------
# targeted re-merge evaluation (ISSUE 8 tentpole)
# ---------------------------------------------------------------------------

def _merge_stream_events(dic):
    """A deterministic update stream that repeatedly merges cliques which
    rewrite rule constants: fresh :idProp edges join group 1 to group 0 and
    group 3 to group 2 (each merge relabels the referenced member's
    representative, so rho(P) changes), then the first edge pair is deleted
    again (clique split — rho reverts, rewriting the rules back)."""
    idp = dic.id_of(":idProp")
    v1, v2 = dic.intern(":mergeval1"), dic.intern(":mergeval2")
    ev1 = np.asarray(
        [[dic.id_of(":e1_0"), idp, v1], [dic.id_of(":e0_0"), idp, v1]],
        np.int32,
    )
    ev2 = np.asarray(
        [[dic.id_of(":e3_0"), idp, v2], [dic.id_of(":e2_0"), idp, v2]],
        np.int32,
    )
    return [("add", ev1), ("add", ev2), ("delete", ev1)]


@pytest.mark.parametrize("fuse", [True, False], ids=["fused", "host_loop"])
def test_remerge_targeted_no_full_plan_evals(fuse):
    """The forward-side acceptance test mirroring ISSUE 5's delete-side one:
    rho re-merges that rewrite rule constants are evaluated merge-anchored
    (mplan) — NO unconstrained whole-rule evaluation on any maintenance
    path — and the store stays oracle-equal after every event.  Asserted in
    both the fused fixpoint and the host round loop."""
    facts, prog, dic = generate(
        n_groups=4, group_size=3, n_spokes_per=3, n_plain=20,
        hierarchy_depth=1, const_rules=4, seed=0,
    )
    events = _merge_stream_events(dic)
    eng = _engine(dic, cap=1 << 11, fuse_rounds=fuse)
    state = eng.materialise_state(facts, prog)
    # the BASE materialisation legitimately requeues whole rules (paper
    # Algorithm 1 semantics, oracle counter parity) — the gate is on the
    # maintenance stream's delta
    base_full = state.stats.full_plan_evals
    base_rw = state.stats.rule_rewrites
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        _assert_state_matches_scratch(eng, state, explicit, prog, dic.n_resources)
    st = state.stats
    assert st.rule_rewrites - base_rw >= 2   # merges rewrote rho(P) repeatedly
    assert st.remerge_targeted >= 2          # ... and were evaluated anchored
    assert st.remerge_full_fallback == 0     # every changed atom had variables
    assert st.full_plan_evals == base_full   # the ISSUE 8 invariant


def test_remerge_head_only_change_needs_no_evaluation():
    """A rule whose HEAD constant merges (body unchanged) needs no
    re-evaluation at all: the sweep re-normalises stored heads, so the rule
    is neither merge-anchored nor requeued — and the store is still right."""
    dic = Dictionary()
    a = dic.intern_many([f":a{i}" for i in range(3)])  # before the rules!
    prog = parse_program([
        "(?x, owl:sameAs, ?y) <- (?x, :idProp, ?v) & (?y, :idProp, ?v)",
        "(?x, :flag, :a2) <- (?x, :q, ?y)",
    ], dic)
    idp, qq = dic.id_of(":idProp"), dic.id_of(":q")
    v, s, t = dic.intern(":v"), dic.intern(":s"), dic.intern(":t")
    facts = np.asarray([[s, qq, t]], np.int32)
    eng = _engine(dic, cap=512)
    state = eng.materialise_state(facts, prog)
    base = (state.stats.remerge_targeted, state.stats.full_plan_evals)
    # merge a2 into the {a0, a1} clique: rho rewrites ONLY rule 2's head
    delta = np.asarray([[ai, idp, v] for ai in a], np.int32)
    eng.add_facts(state, delta)
    explicit = np.concatenate([facts, delta], axis=0)
    _assert_state_matches_scratch(eng, state, explicit, prog, dic.n_resources)
    assert state.stats.rule_rewrites >= 1
    assert state.stats.remerge_targeted == base[0]  # nothing to evaluate
    assert state.stats.full_plan_evals == base[1]
    flag = dic.id_of(":flag")
    assert [s, flag, min(a)] in eng.state_triples(state).tolist()


_MODE_COMBOS = [
    (dict(n_groups=1, group_size=5, n_spokes_per=2, n_plain=8,
          hierarchy_depth=0), 3, "clique_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=25,
          hierarchy_depth=3), 5, "chain_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=30,
          hierarchy_depth=1, chain_rules=True), 7, "dbpedia_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=15,
          hierarchy_depth=1, hometown_groups=1, hometown_size=5), 9,
     "uobm_ish"),
    # merge-heavy + entity-constant rules: update merges rewrite rho(P),
    # so the differential also covers targeted vs whole-rule RE-MERGE
    # evaluation (ISSUE 8), not just the delete-side rederive strategies
    (dict(n_groups=4, group_size=3, n_spokes_per=2, n_plain=15,
          hierarchy_depth=1, const_rules=4), 11, "merge_ish"),
]


def _run_mode_differential(gen_kw, seed, n_events=4, batch=8):
    """targeted == whole-rule requeue == from-scratch, after every event."""
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(
        facts, dic, n_events=n_events, batch=batch, seed=seed
    )
    engines = {
        m: _engine(dic, cap=1 << 11, rederive_mode=m)
        for m in ("targeted", "requeue")
    }
    states = {m: e.materialise_state(facts, prog) for m, e in engines.items()}
    base_full = {m: states[m].stats.full_plan_evals for m in engines}
    explicit = facts
    for i, (op, delta) in enumerate(events):
        explicit = _apply(explicit, op, delta)
        ref = materialise_rew(explicit, prog, dic.n_resources)
        want = _packset(ref.triples())
        for m, e in engines.items():
            (e.add_facts if op == "add" else e.delete_facts)(states[m], delta)
            assert _packset(e.state_triples(states[m])) == want, (i, m, op)
            rep = e.state_rep(states[m])
            assert (rep[: ref.rep.shape[0]] == ref.rep).all(), (i, m, op)
    # the strategies genuinely diverged in mechanism, not just in result
    if states["requeue"].stats.rederive_full_fallback:
        assert states["targeted"].stats.rederive_full_fallback == 0
    # targeted mode NEVER evaluates a whole rule unconstrained during
    # maintenance — neither for delete-side rederivation nor for rho
    # re-merges (the base materialisation's requeues are excluded)
    assert states["targeted"].stats.full_plan_evals == base_full["targeted"]


@pytest.mark.parametrize(
    "gen_kw, seed, _id", _MODE_COMBOS, ids=[c[-1] for c in _MODE_COMBOS]
)
def test_rederive_modes_differential(gen_kw, seed, _id):
    _run_mode_differential(gen_kw, seed)


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without the test extra: seeded combos only
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(
        seed=st.integers(0, 2**16),
        n_events=st.integers(1, 4),
        batch=st.integers(2, 10),
        combo=st.integers(0, len(_MODE_COMBOS) - 1),
    )
    @settings(
        max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large],
    )
    def test_fuzz_rederive_modes_nightly(seed, n_events, batch, combo):
        """Nightly: targeted vs whole-rule requeue vs from-scratch on fuzzed
        streams over the four profile shapes."""
        _run_mode_differential(
            _MODE_COMBOS[combo][0], seed, n_events=n_events, batch=batch
        )


# ---------------------------------------------------------------------------
# delta-mask window fallback (ISSUE 8 satellite)
# ---------------------------------------------------------------------------

def test_delta_mask_fallback_sound_and_counted():
    """Forcing the bounded delta window to overflow (``delta_window=1``)
    makes every multi-row round fall back to all-True plan masks.  The
    fallback used to be silent; now it books ``stats.delta_mask_fallbacks``
    — and it stays SOUND, because all-True masks are a superset that skips
    no plan, so the fixpoint remains oracle-equal after every event."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=2, n_plain=30,
        hierarchy_depth=2, seed=0,
    )
    events = sample_update_stream(facts, dic, n_events=3, batch=8, seed=0)
    eng = _engine(dic, cap=1 << 11, fuse_rounds=False, delta_window=1)
    state = eng.materialise_state(facts, prog)
    assert state.stats.delta_mask_fallbacks > 0  # base rounds overflowed
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        _assert_state_matches_scratch(eng, state, explicit, prog, dic.n_resources)
    # at the default window nothing overflows at this scale — the counter
    # fires only on genuine degradation, not on healthy rounds
    eng2 = _engine(dic, cap=1 << 11, fuse_rounds=False)
    st2 = eng2.materialise_state(facts, prog)
    for op, delta in events:
        (eng2.add_facts if op == "add" else eng2.delete_facts)(st2, delta)
    assert st2.stats.delta_mask_fallbacks == 0


# ---------------------------------------------------------------------------
# edge cases on the engine path
# ---------------------------------------------------------------------------

def test_engine_empty_and_nonexistent_deltas_are_noops():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    before = _packset(eng.state_triples(state))
    r_before = state.r
    eng.add_facts(state, np.zeros((0, 3), np.int32))
    eng.delete_facts(state, np.zeros((0, 3), np.int32))
    eng.add_facts(state, facts)  # re-adding explicit facts is a no-op
    eng.delete_facts(state, np.asarray([[9, 9, 9]], np.int32))  # not explicit
    assert _packset(eng.state_triples(state)) == before
    assert state.r == r_before  # no rounds were spent
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_delete_then_readd_in_one_stream():
    """delete(D); add(D) inside one update stream returns to the original."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20, hierarchy_depth=1
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    before = _packset(eng.state_triples(state))
    rep_before = eng.state_rep(state)
    idp = dic.id_of(":idProp")
    delta = facts[np.flatnonzero(facts[:, 1] == idp)[:3]]
    eng.delete_facts(state, delta)
    assert _packset(eng.state_triples(state)) != before  # the split happened
    eng.add_facts(state, delta)
    assert _packset(eng.state_triples(state)) == before
    assert (eng.state_rep(state) == rep_before).all()
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_delete_everything():
    facts, prog, dic = single_clique(5)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts)
    assert eng.state_triples(state).shape[0] == 0
    assert (eng.state_rep(state) == np.arange(dic.n_resources)).all()


def test_capacity_error_raised_not_truncated():
    """Tombstone-heavy rounds overflow the fixed arena: retracted rows stay
    (marked) in the arena while rederivation inserts fresh rows, so repeated
    delete/re-add churn must raise CapacityError with retry disabled — and
    transparently grow (matching the oracle) with retry enabled."""
    facts, prog, dic = clique_with_spokes(7, 4)
    base = JaxEngine(dic.n_resources, capacity=1 << 10, bind_cap=1 << 10,
                     out_cap=1 << 10, rewrite_cap=1 << 10)
    used = int(np.asarray(base.materialise_state(facts, prog).n_used).sum())

    # an arena with barely more rows than the base store: the first delete's
    # rederive pass (which appends, never reclaims) cannot fit
    snug = used + 2
    eng = JaxEngine(dic.n_resources, capacity=snug, bind_cap=1 << 10,
                    out_cap=1 << 10, rewrite_cap=1 << 10)
    state = eng.materialise_state(facts, prog)
    with pytest.raises(CapacityError):
        eng.delete_facts(state, facts[2:4], retry=False)

    eng2 = JaxEngine(dic.n_resources, capacity=snug, bind_cap=1 << 10,
                     out_cap=1 << 10, rewrite_cap=1 << 10)
    st2 = eng2.materialise_state(facts, prog)
    eng2.delete_facts(st2, facts[2:4])  # retry=True grows the arena
    assert eng2.capacity > snug
    remaining = np.concatenate([facts[:2], facts[4:]], axis=0)
    _assert_state_matches_scratch(eng2, st2, remaining, prog, dic.n_resources)


def test_engine_from_config():
    from repro.configs.sameas_rew import REDUCED

    facts, prog, dic = pex()
    eng = JaxEngine.from_config(REDUCED, n_resources=dic.n_resources)
    assert eng.seed_chunk == REDUCED.seed_chunk
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts[1:2])
    remaining = np.concatenate([facts[:1], facts[2:]], axis=0)
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# mesh-parametrised equivalence (subprocess with 4 fake devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.core.engine_jax import JaxEngine
    from repro.core.materialise import materialise_rew
    from repro.core.triples import apply_op as apply, pack
    from repro.data.generator import generate, sample_update_stream
    from repro.launch.mesh import make_engine_mesh, mesh_size

    assert len(jax.devices()) == 4, jax.devices()

    def packset(x):
        return set(pack(np.asarray(x, np.int32).reshape(-1, 3)).tolist())

    facts, prog, dic = generate(n_groups=2, group_size=3, n_spokes_per=1,
                                n_plain=15, hierarchy_depth=1, const_rules=2,
                                seed=3)
    events = sample_update_stream(facts, dic, n_events=4, batch=8, seed=3)
    # deterministic merge-heavy tail: join the two const-rule entities
    # themselves (the sampled deletes may have split them off their
    # groups, so merging the groups' seeds is not enough) — rho rewrites
    # rule 1's entity constant to the joint rep, then deleting the edge
    # pair splits it back.  The ISSUE 8 full_plan_evals == 0 acceptance,
    # asserted across the whole device matrix.
    idp = dic.id_of(":idProp")
    mv = dic.intern(":mv0")
    merge = np.asarray([[dic.id_of(":e1_2"), idp, mv],
                        [dic.id_of(":e0_2"), idp, mv]], np.int32)
    events = events + [("add", merge), ("delete", merge)]

    finals = {}
    cells = [("m1", make_engine_mesh(1), None, "targeted", True),
             ("m2", make_engine_mesh(2), None, "targeted", True),
             ("m4", make_engine_mesh(4), None, "targeted", True),
             ("m4_routed", make_engine_mesh(4), 256, "targeted", True),
             ("m2_requeue", make_engine_mesh(2), None, "requeue", True),
             ("m2_nofuse", make_engine_mesh(2), None, "targeted", False),
             ("m4_routed_nofuse", make_engine_mesh(4), 256, "targeted", False)]
    for name, mesh, route_cap, rmode, fuse in cells:
        assert mesh_size(mesh) in (1, 2, 4)
        eng = JaxEngine(dic.n_resources, capacity=1 << 10, bind_cap=1 << 10,
                        out_cap=1 << 10, rewrite_cap=1 << 10, mesh=mesh,
                        route_cap=route_cap, seed_chunk=128,
                        rederive_mode=rmode, fuse_rounds=fuse)
        state = eng.materialise_state(facts, prog)
        base_full = state.stats.full_plan_evals
        base_rw = state.stats.rule_rewrites
        explicit = facts
        for op, delta in events:
            explicit = apply(explicit, op, delta)
            (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
            ref = materialise_rew(explicit, prog, dic.n_resources)
            assert packset(eng.state_triples(state)) == packset(ref.triples()), (name, op)
            assert (eng.state_rep(state) == ref.rep).all(), (name, op)
        finals[name] = packset(eng.state_triples(state))
        assert state.stats.rule_rewrites > base_rw, name  # the tail really merged
        if rmode == "targeted":
            assert state.stats.full_plan_evals == base_full, name
            assert state.stats.remerge_targeted >= 1, name
        else:
            assert state.stats.full_plan_evals > base_full, name
    assert len({frozenset(v) for v in finals.values()}) == 1, sorted(finals)
    print("SPMD-INC-OK")
    """
)


@pytest.mark.slow
def test_sharded_deltas_device_count_invariant():
    """The sharded delta path on 1/2/4 virtual devices (gather + owner-routed
    exchange, targeted AND whole-rule-requeue rederivation) is oracle-equal
    per event and device-count invariant."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SPMD-INC-OK" in out.stdout
