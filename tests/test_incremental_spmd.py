"""Sharded incremental maintenance (engine path) vs the from-scratch oracle.

In-process tests run the device path single-device (the same code the mesh
wraps with shard_map); the mesh-parametrised equivalence tests run in a
subprocess with 4 fake CPU devices (``XLA_FLAGS`` must be set before the
first jax import — the pattern of tests/test_distributed.py) and assert
device-count invariance of the final store across 1/2/4 shards plus the
owner-routed exchange variant.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine_jax import CapacityError, JaxEngine
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op as _apply, pack
from repro.data.datasets import clique_with_spokes, pex, single_clique
from repro.data.generator import generate, sample_update_stream


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


def _engine(dic, cap=1 << 10, **kw):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap, **kw,
    )


def _assert_state_matches_scratch(eng, state, explicit, program, n_resources):
    ref = materialise_rew(explicit, program, n_resources)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())
    rep = eng.state_rep(state)
    assert (rep[: ref.rep.shape[0]] == ref.rep).all()
    tail = rep[ref.rep.shape[0] :]
    assert (tail == np.arange(ref.rep.shape[0], rep.shape[0])).all()


# ---------------------------------------------------------------------------
# single-device engine path (in-process)
# ---------------------------------------------------------------------------

def test_engine_add_matches_scratch():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts[:1], prog)
    eng.add_facts(state, facts[1:])
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_add_new_resources_grows_rep():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    new_id = dic.n_resources + 5
    delta = np.asarray([[new_id, facts[0, 1], facts[0, 2]]], np.int32)
    eng.add_facts(state, delta)
    all_facts = np.concatenate([facts, delta], axis=0)
    ref = materialise_rew(all_facts, prog, new_id + 1)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())
    assert (eng.state_rep(state) == ref.rep[: state.n_res]).all()


def test_engine_delete_splits_clique():
    facts, prog, dic = single_clique(6)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts[2:3])  # a2 ~ a3: {a0..a2} | {a3..a5}
    remaining = np.concatenate([facts[:2], facts[3:]], axis=0)
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)
    reps = np.unique(eng.state_rep(state)[np.unique(facts[:, [0, 2]])])
    assert reps.shape[0] == 2
    assert state.stats.suspects_split >= 1
    assert state.stats.overdeleted > 0


def test_engine_delete_derived_sameas_support():
    """Deleting :idProp edges must split the rule-derived clique on-device."""
    facts, prog, dic = generate(
        n_groups=3, group_size=4, n_spokes_per=2, n_plain=30, hierarchy_depth=2
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    idp = dic.id_of(":idProp")
    id_rows = np.flatnonzero(facts[:, 1] == idp)
    delta = facts[id_rows[:2]]
    eng.delete_facts(state, delta)
    remaining = facts[~np.isin(pack(facts), pack(delta))]
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)


def test_engine_update_stream_matches_scratch():
    facts, prog, dic = generate(
        n_groups=3, group_size=3, n_spokes_per=2, n_plain=40,
        hierarchy_depth=2, seed=0,
    )
    events = sample_update_stream(facts, dic, n_events=5, batch=10, seed=0)
    eng = _engine(dic, cap=1 << 11)
    state = eng.materialise_state(facts, prog)
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        _assert_state_matches_scratch(eng, state, explicit, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# edge cases on the engine path
# ---------------------------------------------------------------------------

def test_engine_empty_and_nonexistent_deltas_are_noops():
    facts, prog, dic = pex()
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    before = _packset(eng.state_triples(state))
    r_before = state.r
    eng.add_facts(state, np.zeros((0, 3), np.int32))
    eng.delete_facts(state, np.zeros((0, 3), np.int32))
    eng.add_facts(state, facts)  # re-adding explicit facts is a no-op
    eng.delete_facts(state, np.asarray([[9, 9, 9]], np.int32))  # not explicit
    assert _packset(eng.state_triples(state)) == before
    assert state.r == r_before  # no rounds were spent
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_delete_then_readd_in_one_stream():
    """delete(D); add(D) inside one update stream returns to the original."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20, hierarchy_depth=1
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    before = _packset(eng.state_triples(state))
    rep_before = eng.state_rep(state)
    idp = dic.id_of(":idProp")
    delta = facts[np.flatnonzero(facts[:, 1] == idp)[:3]]
    eng.delete_facts(state, delta)
    assert _packset(eng.state_triples(state)) != before  # the split happened
    eng.add_facts(state, delta)
    assert _packset(eng.state_triples(state)) == before
    assert (eng.state_rep(state) == rep_before).all()
    _assert_state_matches_scratch(eng, state, facts, prog, dic.n_resources)


def test_engine_delete_everything():
    facts, prog, dic = single_clique(5)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts)
    assert eng.state_triples(state).shape[0] == 0
    assert (eng.state_rep(state) == np.arange(dic.n_resources)).all()


def test_capacity_error_raised_not_truncated():
    """Tombstone-heavy rounds overflow the fixed arena: retracted rows stay
    (marked) in the arena while rederivation inserts fresh rows, so repeated
    delete/re-add churn must raise CapacityError with retry disabled — and
    transparently grow (matching the oracle) with retry enabled."""
    facts, prog, dic = clique_with_spokes(7, 4)
    base = JaxEngine(dic.n_resources, capacity=1 << 10, bind_cap=1 << 10,
                     out_cap=1 << 10, rewrite_cap=1 << 10)
    used = int(np.asarray(base.materialise_state(facts, prog).n_used).sum())

    # an arena with barely more rows than the base store: the first delete's
    # rederive pass (which appends, never reclaims) cannot fit
    snug = used + 2
    eng = JaxEngine(dic.n_resources, capacity=snug, bind_cap=1 << 10,
                    out_cap=1 << 10, rewrite_cap=1 << 10)
    state = eng.materialise_state(facts, prog)
    with pytest.raises(CapacityError):
        eng.delete_facts(state, facts[2:4], retry=False)

    eng2 = JaxEngine(dic.n_resources, capacity=snug, bind_cap=1 << 10,
                     out_cap=1 << 10, rewrite_cap=1 << 10)
    st2 = eng2.materialise_state(facts, prog)
    eng2.delete_facts(st2, facts[2:4])  # retry=True grows the arena
    assert eng2.capacity > snug
    remaining = np.concatenate([facts[:2], facts[4:]], axis=0)
    _assert_state_matches_scratch(eng2, st2, remaining, prog, dic.n_resources)


def test_engine_from_config():
    from repro.configs.sameas_rew import REDUCED

    facts, prog, dic = pex()
    eng = JaxEngine.from_config(REDUCED, n_resources=dic.n_resources)
    assert eng.seed_chunk == REDUCED.seed_chunk
    state = eng.materialise_state(facts, prog)
    eng.delete_facts(state, facts[1:2])
    remaining = np.concatenate([facts[:1], facts[2:]], axis=0)
    _assert_state_matches_scratch(eng, state, remaining, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# mesh-parametrised equivalence (subprocess with 4 fake devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.core.engine_jax import JaxEngine
    from repro.core.materialise import materialise_rew
    from repro.core.triples import apply_op as apply, pack
    from repro.data.generator import generate, sample_update_stream
    from repro.launch.mesh import make_engine_mesh, mesh_size

    assert len(jax.devices()) == 4, jax.devices()

    def packset(x):
        return set(pack(np.asarray(x, np.int32).reshape(-1, 3)).tolist())

    facts, prog, dic = generate(n_groups=2, group_size=3, n_spokes_per=1,
                                n_plain=15, hierarchy_depth=1, seed=3)
    events = sample_update_stream(facts, dic, n_events=4, batch=8, seed=3)

    finals = {}
    cells = [("m1", make_engine_mesh(1), None), ("m2", make_engine_mesh(2), None),
             ("m4", make_engine_mesh(4), None), ("m4_routed", make_engine_mesh(4), 256)]
    for name, mesh, route_cap in cells:
        assert mesh_size(mesh) in (1, 2, 4)
        eng = JaxEngine(dic.n_resources, capacity=1 << 10, bind_cap=1 << 10,
                        out_cap=1 << 10, rewrite_cap=1 << 10, mesh=mesh,
                        route_cap=route_cap, seed_chunk=128)
        state = eng.materialise_state(facts, prog)
        explicit = facts
        for op, delta in events:
            explicit = apply(explicit, op, delta)
            (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
            ref = materialise_rew(explicit, prog, dic.n_resources)
            assert packset(eng.state_triples(state)) == packset(ref.triples()), (name, op)
            assert (eng.state_rep(state) == ref.rep).all(), (name, op)
        finals[name] = packset(eng.state_triples(state))
    assert finals["m1"] == finals["m2"] == finals["m4"] == finals["m4_routed"]
    print("SPMD-INC-OK")
    """
)


@pytest.mark.slow
def test_sharded_deltas_device_count_invariant():
    """The sharded delta path on 1/2/4 virtual devices (gather + owner-routed
    exchange) is oracle-equal per event and device-count invariant."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SPMD-INC-OK" in out.stdout
