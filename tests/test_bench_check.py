"""Deterministic bench smoke + the --check regression gate's semantics.

The heavy profiles stay in ``benchmarks/run.py``; tier-1 gets (a) a tiny
deterministic ``run_one`` pass that exercises the full host/engine/scratch
comparison (oracle asserts included) and pins the JSON row schema —
``n_warmup`` and the consistent warm-up exclusion of ISSUE 4's bench
satellite — and (b) pure-function tests of ``compare_incremental``, the
gate ``benchmarks/run.py --check`` fails builds with.
"""

import numpy as np

from benchmarks.bench_incremental import _steady_mask, run_one
from benchmarks.run import compare_incremental


def test_bench_smoke_row_schema():
    kw = dict(
        n_groups=1, group_size=3, n_spokes_per=1, n_plain=12,
        hierarchy_depth=1,
    )
    row = run_one("micro", kw, n_events=3, batch=4, seed=0)
    assert row["dataset"] == "micro"
    assert row["events"] == 3
    # warm-up = each op kind's first occurrence, recorded in the row
    ops = row["per_event"]["ops"]
    assert row["n_warmup"] == len({*ops})
    assert len(row["per_event"]["engine_s"]) == 3
    # steady means exist iff a non-warm-up event exists, and then exclude
    # the warm-up events consistently
    steady_events = [
        t for i, (op, t) in enumerate(zip(ops, row["per_event"]["engine_s"]))
        if op in ops[:i]
    ]
    if steady_events:
        assert row["steady_engine_s_per_event"] is not None
        assert row["steady_engine_s_per_event"] <= max(
            row["per_event"]["engine_s"]
        )
        assert row["speedup_engine_vs_scratch"] is not None
    else:
        assert row["steady_engine_s_per_event"] is None
        assert row["speedup_engine_vs_scratch"] is None


def test_steady_mask_excludes_first_occurrences():
    events = [("add", None), ("delete", None), ("add", None), ("delete", None)]
    assert _steady_mask(events).tolist() == [False, False, True, True]
    # a stream of nothing but first occurrences has NO steady events — the
    # old fallback averaged the compile-laden events back in
    assert _steady_mask(events[:2]).tolist() == [False, False]


def test_compare_incremental_gate():
    baseline = {"rows": [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0},
        {"dataset": "b", "speedup_engine_vs_scratch": 2.0},
        {"dataset": "null", "speedup_engine_vs_scratch": None},
    ]}
    fresh = [
        {"dataset": "a", "speedup_engine_vs_scratch": 0.85},   # -15%: ok
        {"dataset": "b", "speedup_engine_vs_scratch": 1.55},   # -22.5%: fail
        {"dataset": "null", "speedup_engine_vs_scratch": 3.0}, # no baseline
        {"dataset": "new", "speedup_engine_vs_scratch": 0.1},  # not in base
    ]
    problems = compare_incremental(fresh, baseline, tolerance=0.2)
    assert len(problems) == 1 and problems[0].startswith("b:")
    # improvement and exact-threshold values pass
    assert compare_incremental(
        [{"dataset": "a", "speedup_engine_vs_scratch": 0.8}], baseline
    ) == []
    # a fresh null speedup against a real baseline is a regression
    assert compare_incremental(
        [{"dataset": "a", "speedup_engine_vs_scratch": None}], baseline
    ) != []
