"""Deterministic bench smoke + the --check regression gate's semantics.

The heavy profiles stay in ``benchmarks/run.py``; tier-1 gets (a) a tiny
deterministic ``run_one`` pass that exercises the full host/engine/scratch
comparison (oracle asserts included) and pins the JSON row schema —
``n_warmup`` and the consistent warm-up exclusion of ISSUE 4's bench
satellite — and (b) pure-function tests of ``compare_incremental``, the
gate ``benchmarks/run.py --check`` fails builds with.
"""

import numpy as np

from benchmarks.bench_incremental import _steady_mask, run_one
from benchmarks.run import compare_incremental


def test_bench_smoke_row_schema():
    kw = dict(
        n_groups=1, group_size=3, n_spokes_per=1, n_plain=12,
        hierarchy_depth=1,
    )
    row = run_one("micro", kw, n_events=3, batch=4, seed=0)
    assert row["dataset"] == "micro"
    assert row["events"] == 3
    # warm-up = each op kind's first occurrence, recorded in the row
    ops = row["per_event"]["ops"]
    assert row["n_warmup"] == len({*ops})
    assert len(row["per_event"]["engine_s"]) == 3
    # engine-path health counters recorded per profile (ISSUE 5 satellite;
    # ISSUE 8 adds the forward-side re-merge + delta-mask columns and makes
    # them update-stream deltas net of the base materialisation)
    counters = row["engine_counters"]
    assert {
        "index_rebuilds", "capacity_retries", "wide_growth_restarts",
        "rederive_targeted", "rederive_full_fallback", "rederive_seed_rows",
        "rederive_join_width", "full_plan_evals", "rule_rewrites",
        "remerge_targeted", "remerge_full_fallback", "delta_mask_fallbacks",
    } <= set(counters)
    assert all(isinstance(v, int) and v >= 0 for v in counters.values())
    # the invariant run.py --check enforces on every profile: maintenance
    # never falls back to an unconstrained whole-rule evaluation
    assert counters["full_plan_evals"] == 0
    # dispatch ledger (ISSUE 6 satellite): per-event compiled-call counts,
    # steady mean over the same warm-up mask as the time columns, and the
    # per-family totals the DispatchAuditor reconciles
    disp = row["per_event"]["dispatches"]
    assert len(disp) == 3 and all(isinstance(d, int) and d > 0 for d in disp)
    steady_disp = [d for i, (op, d) in enumerate(zip(ops, disp)) if op in ops[:i]]
    if steady_disp:
        assert row["dispatches_per_event"] == round(
            sum(steady_disp) / len(steady_disp), 2
        )
    else:
        assert row["dispatches_per_event"] is None
    fams = row["dispatch_families"]
    assert fams and all(isinstance(v, int) and v > 0 for v in fams.values())
    assert sum(fams.values()) >= sum(disp)  # stream is a subset of lifetime
    # steady means exist iff a non-warm-up event exists, and then exclude
    # the warm-up events consistently
    steady_events = [
        t for i, (op, t) in enumerate(zip(ops, row["per_event"]["engine_s"]))
        if op in ops[:i]
    ]
    if steady_events:
        assert row["steady_engine_s_per_event"] is not None
        assert row["steady_engine_s_per_event"] <= max(
            row["per_event"]["engine_s"]
        )
        assert row["speedup_engine_vs_scratch"] is not None
    else:
        assert row["steady_engine_s_per_event"] is None
        assert row["speedup_engine_vs_scratch"] is None


def test_steady_mask_excludes_first_occurrences():
    events = [("add", None), ("delete", None), ("add", None), ("delete", None)]
    assert _steady_mask(events).tolist() == [False, False, True, True]
    # a stream of nothing but first occurrences has NO steady events — the
    # old fallback averaged the compile-laden events back in
    assert _steady_mask(events[:2]).tolist() == [False, False]


def test_compare_incremental_gate():
    baseline = {"rows": [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0},
        {"dataset": "b", "speedup_engine_vs_scratch": 2.0},
        {"dataset": "null", "speedup_engine_vs_scratch": None},
    ]}
    fresh = [
        {"dataset": "a", "speedup_engine_vs_scratch": 0.85},   # -15%: ok
        {"dataset": "b", "speedup_engine_vs_scratch": 1.55},   # -22.5%: fail
        {"dataset": "null", "speedup_engine_vs_scratch": 3.0}, # no baseline
        {"dataset": "new", "speedup_engine_vs_scratch": 0.1},  # not in base
    ]
    problems = compare_incremental(fresh, baseline, tolerance=0.2)
    assert len(problems) == 1 and problems[0].startswith("b:")
    # improvement and exact-threshold values pass
    assert compare_incremental(
        [{"dataset": "a", "speedup_engine_vs_scratch": 0.8}], baseline
    ) == []
    # a fresh null speedup against a real baseline is a regression
    assert compare_incremental(
        [{"dataset": "a", "speedup_engine_vs_scratch": None}], baseline
    ) != []


def test_compare_incremental_gates_steady_time():
    """The absolute wall-clock axis: a per-event blow-up fails the gate even
    when the speedup column barely moves (the PR 4 uobm_like regression —
    committed speedup so small that the relative gate was vacuous), while
    ordinary engine wall-clock jitter (~30-50% run-to-run at CPU scale)
    stays inside the wider time tolerance."""
    baseline = {"rows": [
        {"dataset": "uobm", "speedup_engine_vs_scratch": 0.0015,
         "steady_engine_s_per_event": 7.30},
        {"dataset": "ok", "speedup_engine_vs_scratch": 1.0,
         "steady_engine_s_per_event": 1.0},
    ]}
    fresh = [
        {"dataset": "uobm", "speedup_engine_vs_scratch": 0.0013,
         "steady_engine_s_per_event": 11.93},  # +63% per event: fail
        {"dataset": "ok", "speedup_engine_vs_scratch": 1.1,
         "steady_engine_s_per_event": 1.45},   # +45% jitter: within 60%
    ]
    problems = compare_incremental(fresh, baseline, tolerance=0.2)
    assert len(problems) == 1
    assert problems[0].startswith("uobm:")
    assert "steady_engine_s_per_event" in problems[0]
    # a faster-per-event run passes; missing time columns are skipped
    assert compare_incremental(
        [{"dataset": "uobm", "speedup_engine_vs_scratch": 0.0015,
          "steady_engine_s_per_event": 5.0}], baseline
    ) == []
    assert compare_incremental(
        [{"dataset": "uobm", "speedup_engine_vs_scratch": 0.0015,
          "steady_engine_s_per_event": None}], baseline
    ) == []
    # the time axis is independently tunable
    assert compare_incremental(
        [{"dataset": "ok", "speedup_engine_vs_scratch": 1.0,
          "steady_engine_s_per_event": 1.45}], baseline,
        time_tolerance=0.3,
    ) != []


def test_compare_incremental_gates_dispatches():
    """The dispatch axis: deterministic compiled-call counts share the tight
    tolerance — a silent extra dispatch per round (the fused-fixpoint
    metric) fails the gate even when wall-clock noise hides it."""
    baseline = {"rows": [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 10.0},
        {"dataset": "old", "speedup_engine_vs_scratch": 1.0},  # pre-PR-6 row
    ]}
    fresh = [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 13.0},  # +30% dispatches: fail
        {"dataset": "old", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 99.0},  # no baseline column: skipped
    ]
    problems = compare_incremental(fresh, baseline, tolerance=0.2)
    assert len(problems) == 1
    assert problems[0].startswith("a:") and "dispatches_per_event" in problems[0]
    # within tolerance, improvements, and null fresh columns all pass
    for d in (11.5, 8.0, None):
        assert compare_incremental(
            [{"dataset": "a", "speedup_engine_vs_scratch": 1.0,
              "dispatches_per_event": d}], baseline,
        ) == [], d


def test_compare_incremental_absolute_dispatch_ceiling():
    """The ceiling axis is baseline-INdependent: a profile over its absolute
    dispatches_per_event bound fails even when the committed baseline is
    equally bad (regenerating a baseline on a regressed build must not
    ratify the regression), and profiles without a ceiling are skipped."""
    baseline = {"rows": [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 50.0},  # baseline itself already blown
    ]}
    fresh = [
        {"dataset": "a", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 49.0},  # under baseline, over ceiling
        {"dataset": "unlisted", "speedup_engine_vs_scratch": 1.0,
         "dispatches_per_event": 999.0},  # no ceiling: skipped
    ]
    problems = compare_incremental(
        fresh, baseline, tolerance=0.2, dispatch_ceilings={"a": 20.0}
    )
    assert len(problems) == 1, problems
    assert problems[0].startswith("a:") and "absolute ceiling" in problems[0]
    # at or under the ceiling passes; null fresh column is skipped; no
    # ceilings dict at all leaves the relative gate's behaviour unchanged
    for d in (20.0, 12.0, None):
        assert compare_incremental(
            [{"dataset": "a", "speedup_engine_vs_scratch": 1.0,
              "dispatches_per_event": d}],
            baseline, dispatch_ceilings={"a": 20.0},
        ) == [], d
    assert compare_incremental(fresh, baseline) == []


def test_compare_incremental_full_plan_evals_axis():
    """The full_plan_evals == 0 axis (ISSUE 8): baseline-independent and
    exact — a maintenance stream that fell back to an unconstrained
    whole-rule evaluation fails the gate on either side, a row carrying
    engine_counters without the counter fails (dropped counters must not
    read as passes), and the gate's own minimal synthetic rows — no
    engine_counters at all — stay out of scope."""
    clean = {"dataset": "a", "speedup_engine_vs_scratch": 1.0,
             "engine_counters": {"full_plan_evals": 0}}
    dirty = {"dataset": "b", "speedup_engine_vs_scratch": 1.0,
             "engine_counters": {"full_plan_evals": 3}}
    dropped = {"dataset": "c", "speedup_engine_vs_scratch": 1.0,
               "engine_counters": {"rederive_targeted": 1}}
    legacy = {"dataset": "d", "speedup_engine_vs_scratch": 1.0}

    problems = compare_incremental([clean, dirty, dropped, legacy], {"rows": []})
    assert len(problems) == 2, problems
    assert any(p.startswith("b:") and "full_plan_evals 3" in p for p in problems)
    assert any(p.startswith("c:") and "missing" in p for p in problems)
    # the committed baseline is gated too: regenerating the JSON on a
    # regressed build cannot ratify nonzero full-plan evaluations
    problems = compare_incremental([clean], {"rows": [dirty]})
    assert len(problems) == 1 and "baseline" in problems[0]
    assert compare_incremental([clean], {"rows": [clean]}) == []


def test_shipped_dispatch_ceilings_cover_all_profiles():
    """Every generator profile the bench runs has a shipped ceiling, and the
    committed baseline itself sits under it — the gate is live, not
    aspirational."""
    import json
    import os

    from benchmarks.run import BASELINE, DISPATCH_CEILINGS
    from repro.data.generator import PROFILES

    assert set(DISPATCH_CEILINGS) >= set(PROFILES), (
        set(PROFILES) - set(DISPATCH_CEILINGS)
    )
    if os.path.exists(BASELINE):
        with open(BASELINE) as fh:
            rows = json.load(fh).get("rows", [])
        assert compare_incremental(
            rows, {"rows": []}, dispatch_ceilings=DISPATCH_CEILINGS
        ) == []
