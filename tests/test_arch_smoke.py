"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_arch
from repro.data import pipeline
from repro.optim import adamw_init, adamw_update

RNG = np.random.default_rng(7)


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree))


LM_ARCHS = [
    "qwen3-moe-235b-a22b", "deepseek-moe-16b", "qwen2-1.5b",
    "smollm-135m", "starcoder2-15b",
]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models import transformer as lm

    spec = get_arch(arch)
    cfg = spec.reduced
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipeline.lm_batch(0, batch=2, seq=16, vocab=cfg.vocab)
    loss, grads = jax.value_and_grad(lm.loss_fn)(
        params, cfg, jnp.asarray(batch["tokens"]), jnp.asarray(batch["labels"])
    )
    assert jnp.isfinite(loss) and float(loss) > 0
    assert _finite(grads)
    opt = adamw_init(params)
    params2, opt2, gn = adamw_update(params, grads, opt)
    assert _finite(params2) and jnp.isfinite(gn)
    # params actually moved
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    from repro.models import transformer as lm

    spec = get_arch(arch)
    cfg = spec.reduced
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    cache = lm.init_cache(cfg, batch=2, max_len=8)
    tok = jnp.asarray(RNG.integers(0, cfg.vocab, 2), jnp.int32)
    logits, cache = lm.decode_step(params, cfg, cache, tok, 0)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


GNN_ARCHS = ["dimenet", "egnn", "gatedgcn", "pna"]


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    import repro.models.gnn.dimenet as m_dimenet
    import repro.models.gnn.egnn as m_egnn
    import repro.models.gnn.gatedgcn as m_gatedgcn
    import repro.models.gnn.pna as m_pna

    mod = {"dimenet": m_dimenet, "egnn": m_egnn, "gatedgcn": m_gatedgcn, "pna": m_pna}[arch]
    spec = get_arch(arch)
    cfg = spec.reduced

    if arch in ("gatedgcn", "pna"):
        batch = pipeline.random_graph(RNG, n_nodes=50, n_edges=200, d_feat=cfg.d_in, n_classes=cfg.n_classes)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
    else:
        b = pipeline.molecule_batch(RNG, n_graphs=4, nodes_per=6, edges_per=14)
        batch = {k: (jnp.asarray(v) if not np.isscalar(v) else v) for k, v in b.items()}
        if arch == "egnn":
            batch["x"] = jnp.asarray(RNG.normal(size=(24, cfg.d_in)).astype(np.float32))
    params = mod.init_params(jax.random.PRNGKey(1), cfg)
    loss, grads = jax.value_and_grad(mod.loss_fn)(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), arch
    assert _finite(grads), arch


def test_egnn_equivariance():
    """Rotating+translating inputs rotates the coordinate output and leaves
    the invariant prediction unchanged."""
    import repro.models.gnn.egnn as m_egnn

    spec = get_arch("egnn")
    cfg = spec.reduced
    b = pipeline.molecule_batch(RNG, n_graphs=2, nodes_per=5, edges_per=12)
    batch = {k: (jnp.asarray(v) if not np.isscalar(v) else v) for k, v in b.items()}
    batch["x"] = jnp.asarray(RNG.normal(size=(10, cfg.d_in)).astype(np.float32))
    params = m_egnn.init_params(jax.random.PRNGKey(3), cfg)
    pred1, pos1 = m_egnn.forward(params, cfg, batch)
    # random rotation via QR + translation
    q, _ = np.linalg.qr(RNG.normal(size=(3, 3)))
    q = jnp.asarray(q.astype(np.float32))
    t = jnp.asarray([1.0, -2.0, 0.5])
    batch2 = dict(batch)
    batch2["pos"] = batch["pos"] @ q + t
    pred2, pos2 = m_egnn.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(pred1), np.asarray(pred2), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(pos1 @ q + t), np.asarray(pos2), rtol=2e-3, atol=2e-3)


def test_fm_smoke_train_and_serve():
    from repro.models import recsys as fm

    spec = get_arch("fm")
    cfg = spec.reduced
    params = fm.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipeline.recsys_batch(0, batch=32, n_fields=cfg.n_fields, rows_per_field=cfg.rows_per_field)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, grads = jax.value_and_grad(fm.loss_fn)(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    assert _finite(grads)
    probs = fm.serve_step(params, cfg, batch)
    assert probs.shape == (32,) and bool(((probs >= 0) & (probs <= 1)).all())
    scores = fm.retrieval_scores(
        params, cfg, batch["ids"][:1], jnp.arange(100, dtype=jnp.int32)
    )
    assert scores.shape == (100,)


def test_fm_sum_square_matches_pallas_kernel():
    """FM forward: jnp interaction path == fused Pallas kernel path."""
    from repro.models import recsys as fm

    spec = get_arch("fm")
    cfg = spec.reduced
    params = fm.init_params(jax.random.PRNGKey(0), cfg)
    batch = pipeline.recsys_batch(1, 16, cfg.n_fields, cfg.rows_per_field)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    a = fm.forward(params, cfg, batch)
    b = fm.forward(params, dataclasses.replace(cfg, use_pallas=True), batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_fm_sameas_rho_unifies_ids():
    """The paper's technique applied to recsys: two IDs merged by rho must
    produce identical scores."""
    from repro.models import recsys as fm

    spec = get_arch("fm")
    cfg = spec.reduced
    params = fm.init_params(jax.random.PRNGKey(0), cfg)
    rho = jnp.arange(cfg.n_rows, dtype=jnp.int32)
    # merge row 7 into row 3 of field 0
    rho = rho.at[7].set(3)
    ids_a = jnp.full((1, cfg.n_fields), 5, jnp.int32).at[0, 0].set(7)
    ids_b = jnp.full((1, cfg.n_fields), 5, jnp.int32).at[0, 0].set(3)
    sa = fm.forward(params, cfg, {"ids": ids_a, "rho": rho})
    sb = fm.forward(params, cfg, {"ids": ids_b, "rho": rho})
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb))


def test_engine_smoke():
    from repro.core.engine_jax import JaxEngine
    from repro.data.datasets import pex

    spec = get_arch("sameas_rew")
    cfg = spec.reduced
    facts, prog, dic = pex()
    eng = JaxEngine(
        dic.n_resources, capacity=cfg.capacity, bind_cap=cfg.bind_cap,
        out_cap=cfg.out_cap, rewrite_cap=cfg.rewrite_cap,
    )
    spo, rep, stats = eng.materialise(facts, prog)
    assert stats.merged_resources == 3


def test_registry_complete():
    assert len(all_archs()) == 11  # 10 assigned + the paper's own workload
    for a in all_archs():
        spec = get_arch(a)
        assert spec.shapes, a
        total = sum(1 for s in spec.shapes)
        assert total >= 2
