"""Fault-tolerance of the train loop: kill/restart bit-identical resume,
NaN guard, straggler hook, heartbeat."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import TrainConfig, Trainer

jax.config.update("jax_platform_name", "cpu")


def make_parts(tmp_path, n_steps=30, ckpt_every=10, lr=1e-2, poison_step=None):
    def init_params():
        k = jax.random.PRNGKey(0)
        return {
            "w": jax.random.normal(k, (8, 4), jnp.float32) * 0.1,
            "b": jnp.zeros((4,), jnp.float32),
        }

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def batch_fn(step):
        rng = np.random.default_rng(100 + step)
        x = rng.normal(size=(16, 8)).astype(np.float32)
        w_true = np.linspace(-1, 1, 32).reshape(8, 4).astype(np.float32)
        y = x @ w_true
        if poison_step is not None and step == poison_step:
            x = x * np.nan
        return {"x": x, "y": y}

    cfg = TrainConfig(
        n_steps=n_steps,
        ckpt_dir=str(tmp_path),
        ckpt_every=ckpt_every,
        async_ckpt=False,
        lr=lr,
        log_every=0,
        heartbeat_path=str(tmp_path / "heartbeat"),
    )
    return loss_fn, init_params, batch_fn, cfg


def test_kill_restart_is_bit_identical(tmp_path):
    loss_fn, init_params, batch_fn, cfg = make_parts(tmp_path / "a")
    ref = Trainer(loss_fn, init_params(), batch_fn, cfg)
    ref_losses = ref.run()

    # interrupted run: train to 17 (checkpoint lands at 10), "crash", restart
    loss_fn, init_params, batch_fn, cfg = make_parts(tmp_path / "b")
    t1 = Trainer(loss_fn, init_params(), batch_fn, cfg)
    t1.run(until=17)  # checkpoints at 10 and (final) 17
    del t1

    t2 = Trainer(loss_fn, init_params(), batch_fn, cfg)
    assert t2.resume()
    assert t2.step == 17
    losses2 = t2.run()
    np.testing.assert_allclose(ref_losses[17:], losses2, rtol=1e-6)
    # end state identical to the uninterrupted run
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6),
        ref.params, t2.params,
    )


def test_loss_decreases(tmp_path):
    loss_fn, init_params, batch_fn, cfg = make_parts(tmp_path, n_steps=60)
    t = Trainer(loss_fn, init_params(), batch_fn, cfg)
    losses = t.run()
    assert np.mean(losses[-10:]) < 0.2 * np.mean(losses[:10])


def test_nan_guard_skips_update(tmp_path):
    loss_fn, init_params, batch_fn, cfg = make_parts(
        tmp_path, n_steps=20, poison_step=5
    )
    t = Trainer(loss_fn, init_params(), batch_fn, cfg)
    losses = t.run()
    assert not np.isfinite(losses[5])
    assert np.isfinite(losses[6])  # recovered: params were not poisoned
    assert np.isfinite(losses[-1])


def test_persistent_nan_aborts(tmp_path):
    def loss_fn(params, batch):
        return jnp.float32(np.nan) * jnp.sum(params["w"])

    _, init_params, batch_fn, cfg = make_parts(tmp_path, n_steps=20)
    t = Trainer(loss_fn, init_params(), batch_fn, cfg)
    with pytest.raises(FloatingPointError):
        t.run()


def test_straggler_hook_and_heartbeat(tmp_path):
    loss_fn, init_params, batch_fn, cfg = make_parts(tmp_path, n_steps=12)
    events = []
    slow = {"armed": True}

    def slow_batch(step):
        if step == 8 and slow["armed"]:
            import time

            time.sleep(0.5)
            slow["armed"] = False
        return batch_fn(step)

    t = Trainer(
        loss_fn, init_params(), slow_batch, cfg,
        on_straggler=lambda s, dt: events.append((s, dt)),
    )
    t.run()
    assert any(s == 8 for s, _ in events), events
    hb = open(cfg.heartbeat_path).read().split()
    assert int(hb[0]) == 11  # last step heartbeat
