"""Differential consistency of the live SPARQL triple store.

The serving oracle (docs/serving.md): every answer the service produces at
epoch ``e`` must equal evaluating the same query over the *from-scratch* REW
materialisation of the explicit fact set as of epoch ``e`` — no matter how
queries interleave with the phases of running maintenance operations.  The
scheduler is deterministic, so randomized interleavings (including queries
admitted between an overdelete wave and its rederivation) are constructed
exactly and replayed against the oracle.

Fuzz tiers follow the PR 2 harness pattern: seeded fallback combos always
run; with hypothesis installed a quick budget runs in tier-1 and a larger
``slow``-marked budget nightly.
"""

import numpy as np
import pytest

from repro.core.engine_jax import JaxEngine
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op, pack
from repro.data.datasets import single_clique
from repro.data.generator import generate, sample_update_stream
from repro.serve.triple_store import TripleStore
from repro.sparql import Query, evaluate


def _engine(dic, cap=1 << 11):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap,
    )


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


class _Oracle:
    """Explicit-set bookkeeping + from-scratch answers per completed epoch."""

    def __init__(self, facts, program, dic):
        self.program, self.dic = program, dic
        self.explicit_at = {0: np.asarray(facts, np.int32)}
        self._mat = {}

    def apply(self, ticket):
        """Record a completed update ticket (call in epoch order)."""
        prev = self.explicit_at[ticket.epoch - 1]
        self.explicit_at[ticket.epoch] = apply_op(prev, ticket.op, ticket.delta)

    def mat(self, epoch):
        if epoch not in self._mat:
            self._mat[epoch] = materialise_rew(
                self.explicit_at[epoch], self.program, self.dic.n_resources
            )
        return self._mat[epoch]

    def answer(self, q, epoch):
        ref = self.mat(epoch)
        return evaluate(q, ref.triples(), ref.rep, self.dic)


def _run_trace(gen_kw, seed, n_events, batch, ticks_seed, cap=1 << 11):
    """Feed a mixed trace through the scheduler under a randomized tick
    pattern, then hold every answer to the oracle at its reported epoch."""
    facts, prog, dic = generate(**gen_kw, seed=seed)
    trace = sample_update_stream(
        facts, dic, n_events=n_events, batch=batch, p_query=0.5, seed=seed
    )
    if not any(op == "query" for op, _ in trace):
        trace.append(
            sample_update_stream(
                facts, dic, n_events=1, batch=1, p_query=1.0, seed=seed + 1
            )[0]
        )
    store = TripleStore(facts, prog, dic, engine=_engine(dic, cap))
    rng = np.random.default_rng(ticks_seed)
    updates, queries = [], []
    for op, payload in trace:
        if op == "query":
            queries.append(store.submit_query(payload))
        else:
            updates.append(store.submit_update(op, payload))
        # 0 ticks lets work pile up; >0 races reads against update phases
        for _ in range(int(rng.integers(0, 3))):
            store.step()
    store.drain()

    assert all(t.status == "done" for t in updates + queries)
    assert store.epoch == len(updates)  # one epoch per admitted update
    oracle = _Oracle(facts, prog, dic)
    for t in sorted(updates, key=lambda t: t.epoch):
        oracle.apply(t)
    # the published snapshot is the newest epoch's fixpoint
    ref = oracle.mat(store.epoch)
    assert _packset(store.snapshot.triples) == _packset(ref.triples())
    assert (store.snapshot.rho.rep[: ref.rep.shape[0]] == ref.rep).all()
    for t in queries:
        assert t.answer == oracle.answer(t.query, t.epoch), (
            f"query {t.uid} diverged from the epoch-{t.epoch} oracle"
        )
    return store, queries


# ---------------------------------------------------------------------------
# differential consistency across workload profiles
# ---------------------------------------------------------------------------

_TRACE_PROFILES = [
    ("chain_like", dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=20,
                        hierarchy_depth=1, chain_rules=True), 3),
    ("clique_like", dict(n_groups=2, group_size=5, n_spokes_per=2, n_plain=10,
                         hierarchy_depth=1), 5),
    ("dbpedia_like", dict(n_groups=2, group_size=3, n_spokes_per=2, n_plain=60,
                          hierarchy_depth=2, chain_rules=True), 7),
]


@pytest.mark.parametrize(
    "gen_kw, seed", [(kw, s) for _n, kw, s in _TRACE_PROFILES],
    ids=[n for n, _kw, _s in _TRACE_PROFILES],
)
def test_differential_consistency(gen_kw, seed):
    _run_trace(gen_kw, seed=seed, n_events=6, batch=8, ticks_seed=seed)


# ---------------------------------------------------------------------------
# scheduled edge cases on the snapshot API
# ---------------------------------------------------------------------------

def test_query_admitted_between_overdelete_and_rederive():
    """A query admitted after the overdelete wave finalises (the live arena
    hides tombstoned-but-not-yet-rederived rows) must be answered at the
    previous epoch's fixpoint — evaluating the live mid-round store instead
    would lose answers."""
    facts, prog, dic = generate(
        n_groups=1, group_size=4, n_spokes_per=3, n_plain=0,
        hierarchy_depth=0, seed=0,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    spoke = dic.id_of(":spoke")
    q = Query([(-1, spoke, -2)], [], [-1], False)
    baseline = store.query_now(q)
    assert baseline.epoch == 0 and sum(baseline.answer.values()) > 0

    idp = dic.id_of(":idProp")
    edge = facts[np.flatnonzero(facts[:, 1] == idp)[:1]]
    t = store.submit_update("delete", edge)
    ticks = 0
    while store.inflight_phase != "overdeleted":
        store.step()
        ticks += 1
        assert ticks < 50, "never reached the mid-overdelete phase"
    assert t.status == "running"

    # the live arena is mid-round: rows the rederive pass will restore are
    # hidden, so reading it directly WOULD be wrong...
    live = store.engine.state_triples(store.state)
    assert _packset(live) < _packset(store.snapshot.triples)
    assert evaluate(q, live, store.engine.state_rep(store.state), dic) \
        != baseline.answer

    # ...but the admitted query reads the published epoch-0 snapshot
    mid = store.submit_query(q)
    store.step()
    assert mid.status == "done" and mid.epoch == 0
    assert mid.answer == baseline.answer

    store.drain()
    after = store.query_now(q)
    assert after.epoch == 1
    ref = materialise_rew(apply_op(facts, "delete", edge), prog, dic.n_resources)
    assert after.answer == evaluate(q, ref.triples(), ref.rep, dic)


def test_query_admitted_at_rederive_phase_reads_published_snapshot():
    """The targeted-rederivation phase ("rederive": head-bound joins done,
    forward fixpoint still pending) is a scheduler yield point like any
    other — a query admitted there must be served at the previous epoch's
    fixpoint, not the live mid-operation arena."""
    facts, prog, dic = generate(
        n_groups=1, group_size=4, n_spokes_per=3, n_plain=0,
        hierarchy_depth=0, seed=0,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    spoke = dic.id_of(":spoke")
    q = Query([(-1, spoke, -2)], [], [-1], False)
    baseline = store.query_now(q)

    idp = dic.id_of(":idProp")
    edge = facts[np.flatnonzero(facts[:, 1] == idp)[:1]]
    store.submit_update("delete", edge)
    ticks = 0
    while store.inflight_phase != "rederive":
        store.step()
        ticks += 1
        assert ticks < 100, "never reached the rederive phase"
    mid = store.submit_query(q)
    store.step()
    assert mid.status == "done" and mid.epoch == 0
    assert mid.answer == baseline.answer

    store.drain()
    after = store.query_now(q)
    assert after.epoch == 1
    ref = materialise_rew(apply_op(facts, "delete", edge), prog, dic.n_resources)
    assert after.answer == evaluate(q, ref.triples(), ref.rep, dic)


def test_split_then_query_old_representative_expands_post_split():
    """Clique split followed immediately by a query over the old
    representative: the answer must expand through the POST-split rho."""
    facts, prog, dic = single_clique(6)
    store = TripleStore(facts, prog, dic, engine=_engine(dic, cap=256))
    sa = dic.id_of("owl:sameAs")
    a = [dic.id_of(f":a{i}") for i in range(6)]
    q_old_rep = Query([(-1, sa, a[0])], [], [-1], False)
    pre = store.query_now(q_old_rep)
    assert pre.answer == {(f":a{i}",): 1 for i in range(6)}

    store.submit_update("delete", facts[2:3])  # a2 ~ a3 -> {a0,a1,a2}|{a3,a4,a5}
    store.drain()
    post = store.query_now(q_old_rep)
    assert post.epoch == 1
    assert post.answer == {(":a0",): 1, (":a1",): 1, (":a2",): 1}
    # the old representative no longer speaks for the severed half
    q_new_rep = Query([(-1, sa, a[4])], [], [-1], False)
    assert store.query_now(q_new_rep).answer == {
        (":a3",): 1, (":a4",): 1, (":a5",): 1,
    }


def test_snapshot_isolated_from_maintenance_and_noop_epochs():
    """Published snapshots are immutable across later maintenance; no-op
    updates still cross an epoch barrier (their fixpoint is the unchanged
    store), so readers' epochs stay monotone and attributable."""
    facts, prog, dic = single_clique(5)
    store = TripleStore(facts, prog, dic, engine=_engine(dic, cap=256))
    snap0 = store.snapshot
    before = _packset(snap0.triples)
    rho0 = snap0.rho.rep.copy()

    store.submit_update("delete", facts[1:2])
    store.drain()
    assert store.epoch == 1 and store.snapshot is not snap0
    # the old view is untouched by the epoch that ran after it
    assert _packset(snap0.triples) == before
    assert (snap0.rho.rep == rho0).all()
    assert not snap0.rho.rep.flags.writeable

    # no-op update: delete of a non-explicit row
    t = store.submit_update("delete", np.asarray([[9, 9, 9]], np.int32))
    store.drain()
    assert t.status == "done" and t.epoch == 2 and store.epoch == 2
    assert _packset(store.snapshot.triples) == _packset(
        store.engine.state_triples(store.state)
    )


def test_query_constant_unseen_at_serving_epoch():
    """A query constant interned AFTER the published snapshot's rho was
    frozen (e.g. a resource a concurrent add is about to introduce) must be
    treated as a singleton — an empty match, never an IndexError killing
    the scheduler — and must resolve normally once its epoch completes."""
    facts, prog, dic = single_clique(4)
    store = TripleStore(facts, prog, dic, engine=_engine(dic, cap=256))
    sa = dic.id_of("owl:sameAs")
    fresh = dic.intern(":arrives-later")
    assert fresh >= store.snapshot.n_res
    q = Query([(-1, sa, fresh)], [], [-1], False)

    # race the query against the add that introduces the fresh resource
    store.submit_update(
        "add", np.asarray([[fresh, sa, dic.id_of(":a0")]], np.int32)
    )
    early = store.submit_query(q)
    store.step()
    assert early.status == "done" and early.epoch == 0
    assert early.answer == {}  # unseen singleton: no match, no crash
    store.drain()
    late = store.query_now(q)
    assert late.epoch == 1
    # fresh ~ a0 merged the clique: the constant now expands to all members
    assert late.answer == {
        (":a0",): 1, (":a1",): 1, (":a2",): 1, (":a3",): 1,
        (":arrives-later",): 1,
    }


def test_mixed_trace_generator_shapes():
    """p_query=0 keeps the update-only contract; p_query=1 yields queries."""
    facts, _prog, dic = single_clique(4)
    upd = sample_update_stream(facts, dic, n_events=4, batch=4, seed=0)
    assert all(op in ("add", "delete") for op, _ in upd)
    qs = sample_update_stream(
        facts, dic, n_events=4, batch=4, p_query=1.0, seed=0
    )
    assert all(op == "query" for op, _ in qs)
    for _op, q in qs:
        assert isinstance(q, Query) and q.select
        assert all(len(atom) == 3 for atom in q.patterns)


# ---------------------------------------------------------------------------
# fuzz of interleaved query/update schedules (PR 2 harness pattern)
# ---------------------------------------------------------------------------

_FUZZ_COMBOS = [
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=15,
          hierarchy_depth=1), 19, 5, 6, 23),
    (dict(n_groups=1, group_size=4, n_spokes_per=2, n_plain=5,
          hierarchy_depth=0), 29, 6, 5, 31),
]


@pytest.mark.parametrize(
    "gen_kw, seed, n_events, batch, ticks_seed", _FUZZ_COMBOS,
    ids=["serve_basic", "serve_dense"],
)
def test_fuzz_fallback_schedules(gen_kw, seed, n_events, batch, ticks_seed):
    """Seeded interleaving fuzz that runs without hypothesis installed."""
    _run_trace(gen_kw, seed, n_events, batch, ticks_seed)


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without the test extra: fallback fuzz only
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _sched_params = given(
        seed=st.integers(0, 2**16),
        ticks_seed=st.integers(0, 2**16),
        n_events=st.integers(2, 6),
        batch=st.integers(2, 8),
        n_groups=st.integers(1, 2),
        group_size=st.integers(2, 4),
        n_plain=st.integers(0, 15),
    )
    _fuzz_settings = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )

    def _fuzz_body(seed, ticks_seed, n_events, batch, n_groups, group_size,
                   n_plain):
        gen_kw = dict(
            n_groups=n_groups, group_size=group_size, n_spokes_per=1,
            n_plain=n_plain, hierarchy_depth=1,
        )
        _run_trace(gen_kw, seed, n_events, batch, ticks_seed)

    # quick budget for tier-1; hypothesis shrinks failures to a minimal
    # schedule (fewest events, smallest graph, simplest tick pattern)
    test_fuzz_interleaved_schedules = _sched_params(
        settings(max_examples=5, **_fuzz_settings)(_fuzz_body)
    )

    # nightly tier: larger example budget, deselectable via -m "not slow"
    test_fuzz_interleaved_schedules_nightly = pytest.mark.slow(
        _sched_params(settings(max_examples=50, **_fuzz_settings)(_fuzz_body))
    )


# ---------------------------------------------------------------------------
# bench smoke: the tiny profile must run end-to-end (keeps the bench alive)
# ---------------------------------------------------------------------------

def test_bench_serve_smoke(tmp_path):
    from benchmarks.bench_serve_updates import main

    out = tmp_path / "BENCH_serve.json"
    rows = main(
        profiles={"smoke": dict(
            n_groups=2, group_size=3, n_spokes_per=1, n_plain=20,
            hierarchy_depth=1,
        )},
        out_json=str(out),
        n_updates=2, batch=6, n_queries=4,
    )
    assert out.exists()
    (row,) = rows
    assert row["epochs"] == 2 and row["n_queries_busy"] > 0
    # the acceptance contract: latency recorded with AND without concurrent
    # maintenance epochs
    assert row["idle_query_ms"]["mean"] >= 0
    assert row["busy_query_ms"]["mean"] > 0
    assert row["idle_query_ms"]["p99"] >= row["idle_query_ms"]["p50"]
    # snapshot build cost is its own column (never inside query latency):
    # construction + one entry per epoch barrier
    assert row["snapshot_build_ms"]["mean"] > 0
    assert row["batched_speedup"] > 0 and row["audit_problems"] == []
    cl = row["closed_loop"]
    assert cl["epochs_completed"] == cl["updates_submitted"] == 2
    assert cl["achieved_qps"] > 0
    assert cl["latency_ms"]["p99"] >= cl["latency_ms"]["p50"] >= 0
    import json

    doc = json.loads(out.read_text())
    assert doc["rows"][0]["dataset"] == "smoke"
