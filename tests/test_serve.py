"""Serving engine: continuous batching correctness — batched decode with
per-slot positions must reproduce one-at-a-time greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as lm
from repro.serve import Request, ServeEngine


def tiny():
    cfg = get_arch("smollm-135m").reduced
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(params, cfg, prompt, n_new):
    """Sequential reference: prefill + single-sequence decode_step."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = lm.prefill(params, cfg, toks)
    # re-home the prefill cache into a max_len arena
    max_len = len(prompt) + n_new + 1
    arena = lm.init_cache(cfg, 1, max_len)
    for key in ("k", "v"):
        arena[key] = jax.lax.dynamic_update_slice(
            arena[key], cache[key], (0, 0, 0, 0, 0)
        )
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n_new - 1):
        logits, arena = lm.decode_step(params, cfg, arena, tok, jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([out[-1]], jnp.int32)
        pos += 1
    return out


def test_engine_matches_sequential_greedy():
    cfg, params = tiny()
    prompts = [[5, 9, 2], [7, 7], [1, 2, 3, 4]]
    n_new = 6
    refs = [greedy_reference(params, cfg, p, n_new) for p in prompts]

    eng = ServeEngine(params, cfg, n_slots=2, max_len=32, eos_id=-1)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert len(done) == 3
    by_uid = {r.uid: r.out for r in done}
    for i, ref in enumerate(refs):
        assert by_uid[i] == ref, f"req {i}: {by_uid[i]} != {ref}"


def test_more_requests_than_slots_all_finish():
    cfg, params = tiny()
    eng = ServeEngine(params, cfg, n_slots=2, max_len=24, eos_id=-1)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=[i + 1, i + 2], max_new=4))
    done = eng.run()
    assert sorted(r.uid for r in done) == list(range(5))
    assert all(len(r.out) == 4 for r in done)


def test_eos_eviction_frees_slot():
    cfg, params = tiny()
    # find which token the model emits first, use it as EOS for req 0
    eng0 = ServeEngine(params, cfg, n_slots=1, max_len=24, eos_id=-1)
    eng0.submit(Request(uid=0, prompt=[3, 1], max_new=3))
    first = eng0.run()[0].out[0]

    eng = ServeEngine(params, cfg, n_slots=1, max_len=24, eos_id=first)
    eng.submit(Request(uid=0, prompt=[3, 1], max_new=8))
    eng.submit(Request(uid=1, prompt=[4, 4], max_new=2))
    done = eng.run()
    assert done[0].uid == 0 and len(done[0].out) == 1  # stopped at EOS
    assert done[1].uid == 1 and len(done[1].out) == 2
