"""Incremental materialisation maintenance vs the from-scratch oracle.

The oracle is Theorem-1 style: after any sequence of add/delete updates, the
incremental state must equal the from-scratch REW materialisation of the
updated explicit fact set — same rho (min-ID representatives are
order-independent, so reps must match exactly), same normal-form store, and
therefore the same expansion T^rho.
"""

import numpy as np
import pytest

from repro.core.incremental import (
    add_facts,
    delete_facts,
    materialise_incremental,
    normal_forms,
)
from repro.core.materialise import expand, materialise_rew
from repro.core.triples import pack
from repro.data.datasets import pex, single_clique
from repro.data.generator import generate, sample_update_stream


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


def _explicit_apply(explicit, op, delta):
    """Oracle-side explicit-set bookkeeping (same semantics as the state)."""
    delta = np.asarray(delta, np.int32).reshape(-1, 3)
    cur = _packset(explicit)
    if op == "add":
        cur |= _packset(delta)
    else:
        cur -= _packset(delta)
    from repro.core.triples import unpack

    keys = np.asarray(sorted(cur), dtype=np.int64)
    return unpack(keys) if keys.shape[0] else np.zeros((0, 3), np.int32)


def assert_matches_scratch(state, explicit, program, n_resources, expand_check=False):
    ref = materialise_rew(explicit, program, n_resources)
    assert _packset(state.triples()) == _packset(ref.triples())
    assert (state.rep[: ref.rep.shape[0]] == ref.rep).all()
    # the incremental rep may be longer (grown by adds); the tail is identity
    tail = state.rep[ref.rep.shape[0] :]
    assert (tail == np.arange(ref.rep.shape[0], state.rep.shape[0])).all()
    if expand_check:
        lhs = expand(state.triples(), state.rep)
        rhs = expand(ref.triples(), ref.rep)
        assert lhs == rhs


# ---------------------------------------------------------------------------
# additions
# ---------------------------------------------------------------------------

def test_add_matches_scratch_pex():
    facts, prog, dic = pex()
    base, extra = facts[:1], facts[1:]
    state = materialise_incremental(base, prog, dic.n_resources)
    add_facts(state, extra)
    assert_matches_scratch(state, facts, prog, dic.n_resources, expand_check=True)


def test_add_new_resources_grows_rep():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    new_id = dic.n_resources + 5
    delta = np.asarray([[new_id, facts[0, 1], facts[0, 2]]], np.int32)
    add_facts(state, delta)
    all_facts = np.concatenate([facts, delta], axis=0)
    assert_matches_scratch(state, all_facts, prog, new_id + 1)


def test_add_empty_delta_is_noop():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    add_facts(state, np.zeros((0, 3), np.int32))
    add_facts(state, facts)  # re-adding explicit facts is also a no-op
    assert _packset(state.triples()) == before
    assert_matches_scratch(state, facts, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# deletions and clique splitting
# ---------------------------------------------------------------------------

def test_delete_sameas_edge_splits_clique():
    facts, prog, dic = single_clique(6)  # a0~a1~...~a5, one clique
    state = materialise_incremental(facts, prog, dic.n_resources)
    mid = facts[2:3]  # a2 ~ a3: splits into {a0,a1,a2} and {a3,a4,a5}
    delete_facts(state, mid)
    remaining = np.concatenate([facts[:2], facts[3:]], axis=0)
    assert_matches_scratch(
        state, remaining, prog, dic.n_resources, expand_check=True
    )
    # the split is observable: two cliques instead of one
    reps = np.unique(state.rep[np.unique(facts[:, [0, 2]])])
    assert reps.shape[0] == 2


def test_delete_derived_sameas_support():
    """Deleting one :idProp edge must split the rule-derived clique."""
    facts, prog, dic = generate(
        n_groups=3, group_size=4, n_spokes_per=2, n_plain=30, hierarchy_depth=2
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    idp = dic.id_of(":idProp")
    id_rows = np.flatnonzero(facts[:, 1] == idp)
    delta = facts[id_rows[:2]]
    delete_facts(state, delta)
    remaining = facts[~np.isin(pack(facts), pack(delta))]
    assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_delete_empty_and_unknown_delta_is_noop():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    delete_facts(state, np.zeros((0, 3), np.int32))
    delete_facts(state, np.asarray([[9, 9, 9]], np.int32))  # not explicit
    assert _packset(state.triples()) == before
    assert_matches_scratch(state, facts, prog, dic.n_resources)


def test_delete_everything():
    for ds in (lambda: pex(), lambda: single_clique(5)):
        facts, prog, dic = ds()
        state = materialise_incremental(facts, prog, dic.n_resources)
        delete_facts(state, facts)
        assert state.triples().shape[0] == 0
        assert (state.rep == np.arange(dic.n_resources)).all()
        assert_matches_scratch(
            state, np.zeros((0, 3), np.int32), prog, dic.n_resources
        )


def test_clique_split_property():
    """Property-style: deleting ANY random subset of sameAs edges (plus the
    empty and full subsets) and re-materialising equals the incremental
    result — including payload triples hanging off the clique."""
    from repro.data.datasets import clique_with_spokes

    facts, prog, dic = clique_with_spokes(7, 4)
    sa_rows = np.flatnonzero(facts[:, 1] == dic.id_of("owl:sameAs"))
    rng = np.random.default_rng(42)
    subsets = [np.zeros(0, np.int64), sa_rows]  # edge cases first
    for _ in range(6):
        m = int(rng.integers(1, sa_rows.shape[0] + 1))
        subsets.append(rng.choice(sa_rows, size=m, replace=False))
    for sub in subsets:
        state = materialise_incremental(facts, prog, dic.n_resources)
        delta = facts[np.asarray(sub, dtype=np.int64)]
        delete_facts(state, delta)
        remaining = (
            facts[~np.isin(pack(facts), pack(delta))] if delta.shape[0] else facts
        )
        assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_add_then_delete_roundtrip():
    """add(D); delete(D) returns to the original materialisation."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20, hierarchy_depth=1
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    rep_before = state.rep.copy()
    idp = dic.id_of(":idProp")
    # a bridge edge that merges two previously-distinct cliques
    g0 = facts[facts[:, 1] == idp][0, 0]
    g1 = facts[facts[:, 1] == idp][-1, 0]
    vid = dic.intern(":bridge")
    bridge = np.asarray(
        [[g0, idp, vid], [g1, idp, vid]], np.int32
    )
    add_facts(state, bridge)
    assert _packset(state.triples()) != before  # the merge happened
    delete_facts(state, bridge)
    assert _packset(state.triples()) == before
    assert (state.rep[: rep_before.shape[0]] == rep_before).all()


# ---------------------------------------------------------------------------
# generated update streams (the acceptance-criteria oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "gen_kw, seed",
    [
        (dict(n_groups=3, group_size=3, n_spokes_per=2, n_plain=40,
              hierarchy_depth=2), 0),
        (dict(n_groups=2, group_size=4, n_spokes_per=1, n_plain=30,
              hierarchy_depth=1, chain_rules=True), 1),
        (dict(n_groups=4, group_size=3, n_spokes_per=2, n_plain=25,
              hierarchy_depth=2, hometown_groups=1, hometown_size=5), 2),
    ],
    ids=["claros_ish", "chains_ish", "uobm_ish"],
)
def test_update_streams_match_scratch(gen_kw, seed):
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(
        facts, dic, n_events=5, batch=10, seed=seed
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    explicit = facts
    for op, delta in events:
        explicit = _explicit_apply(explicit, op, delta)
        if op == "add":
            add_facts(state, delta)
        else:
            delete_facts(state, delta)
        assert_matches_scratch(state, explicit, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# kernel-batched normal forms + engine integration
# ---------------------------------------------------------------------------

def test_normal_forms_kernel_parity():
    rng = np.random.default_rng(0)
    rep = np.arange(300, dtype=np.int32)
    rep[rng.integers(0, 300, size=60)] = rng.integers(0, 50, size=60)
    from repro.core.uf import compress_np

    rep = compress_np(rep)
    spo = rng.integers(0, 300, size=(200, 3)).astype(np.int32)
    np_out = normal_forms(spo, rep, use_kernel=False)
    k_out = normal_forms(spo, rep, use_kernel=True)
    assert (np_out == k_out).all()


def test_delete_with_kernel_normal_forms():
    facts, prog, dic = single_clique(5)
    state = materialise_incremental(
        facts, prog, dic.n_resources, use_kernel=True
    )
    delete_facts(state, facts[1:2])
    remaining = np.concatenate([facts[:1], facts[2:]], axis=0)
    assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_engine_materialise_incremental():
    from repro.core.engine_jax import JaxEngine

    facts, prog, dic = pex()
    updates = [
        ("add", np.asarray([[facts[0, 0], facts[0, 1], facts[2, 2]]], np.int32)),
        ("delete", facts[1:2]),
    ]
    eng = JaxEngine(
        dic.n_resources, capacity=256, bind_cap=256, out_cap=256, rewrite_cap=256
    )
    spo, rep, stats = eng.materialise_incremental(facts, prog, updates)

    explicit = facts
    for op, delta in updates:
        explicit = _explicit_apply(explicit, op, delta)
    ref = materialise_rew(explicit, prog, dic.n_resources)
    assert _packset(spo) == _packset(ref.triples())
    assert (rep == ref.rep).all()
