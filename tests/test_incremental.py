"""Incremental materialisation maintenance vs the from-scratch oracle.

The oracle is Theorem-1 style: after any sequence of add/delete updates, the
incremental state must equal the from-scratch REW materialisation of the
updated explicit fact set — same rho (min-ID representatives are
order-independent, so reps must match exactly), same normal-form store, and
therefore the same expansion T^rho.
"""

import numpy as np
import pytest

from repro.core.incremental import (
    add_facts,
    delete_facts,
    materialise_incremental,
    normal_forms,
)
from repro.core.materialise import expand, materialise_rew
from repro.core.triples import pack
from repro.data.datasets import pex, single_clique
from repro.data.generator import generate, sample_update_stream


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


def _explicit_apply(explicit, op, delta):
    """Oracle-side explicit-set bookkeeping (same semantics as the state)."""
    from repro.core.triples import apply_op

    return apply_op(explicit, op, delta)


def assert_matches_scratch(state, explicit, program, n_resources, expand_check=False):
    ref = materialise_rew(explicit, program, n_resources)
    assert _packset(state.triples()) == _packset(ref.triples())
    assert (state.rep[: ref.rep.shape[0]] == ref.rep).all()
    # the incremental rep may be longer (grown by adds); the tail is identity
    tail = state.rep[ref.rep.shape[0] :]
    assert (tail == np.arange(ref.rep.shape[0], state.rep.shape[0])).all()
    if expand_check:
        lhs = expand(state.triples(), state.rep)
        rhs = expand(ref.triples(), ref.rep)
        assert lhs == rhs


# ---------------------------------------------------------------------------
# additions
# ---------------------------------------------------------------------------

def test_add_matches_scratch_pex():
    facts, prog, dic = pex()
    base, extra = facts[:1], facts[1:]
    state = materialise_incremental(base, prog, dic.n_resources)
    add_facts(state, extra)
    assert_matches_scratch(state, facts, prog, dic.n_resources, expand_check=True)


def test_add_new_resources_grows_rep():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    new_id = dic.n_resources + 5
    delta = np.asarray([[new_id, facts[0, 1], facts[0, 2]]], np.int32)
    add_facts(state, delta)
    all_facts = np.concatenate([facts, delta], axis=0)
    assert_matches_scratch(state, all_facts, prog, new_id + 1)


def test_add_empty_delta_is_noop():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    add_facts(state, np.zeros((0, 3), np.int32))
    add_facts(state, facts)  # re-adding explicit facts is also a no-op
    assert _packset(state.triples()) == before
    assert_matches_scratch(state, facts, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# deletions and clique splitting
# ---------------------------------------------------------------------------

def test_delete_sameas_edge_splits_clique():
    facts, prog, dic = single_clique(6)  # a0~a1~...~a5, one clique
    state = materialise_incremental(facts, prog, dic.n_resources)
    mid = facts[2:3]  # a2 ~ a3: splits into {a0,a1,a2} and {a3,a4,a5}
    delete_facts(state, mid)
    remaining = np.concatenate([facts[:2], facts[3:]], axis=0)
    assert_matches_scratch(
        state, remaining, prog, dic.n_resources, expand_check=True
    )
    # the split is observable: two cliques instead of one
    reps = np.unique(state.rep[np.unique(facts[:, [0, 2]])])
    assert reps.shape[0] == 2


def test_delete_derived_sameas_support():
    """Deleting one :idProp edge must split the rule-derived clique."""
    facts, prog, dic = generate(
        n_groups=3, group_size=4, n_spokes_per=2, n_plain=30, hierarchy_depth=2
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    idp = dic.id_of(":idProp")
    id_rows = np.flatnonzero(facts[:, 1] == idp)
    delta = facts[id_rows[:2]]
    delete_facts(state, delta)
    remaining = facts[~np.isin(pack(facts), pack(delta))]
    assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_delete_empty_and_unknown_delta_is_noop():
    facts, prog, dic = pex()
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    delete_facts(state, np.zeros((0, 3), np.int32))
    delete_facts(state, np.asarray([[9, 9, 9]], np.int32))  # not explicit
    assert _packset(state.triples()) == before
    assert_matches_scratch(state, facts, prog, dic.n_resources)


def test_delete_everything():
    for ds in (lambda: pex(), lambda: single_clique(5)):
        facts, prog, dic = ds()
        state = materialise_incremental(facts, prog, dic.n_resources)
        delete_facts(state, facts)
        assert state.triples().shape[0] == 0
        assert (state.rep == np.arange(dic.n_resources)).all()
        assert_matches_scratch(
            state, np.zeros((0, 3), np.int32), prog, dic.n_resources
        )


def test_clique_split_property():
    """Property-style: deleting ANY random subset of sameAs edges (plus the
    empty and full subsets) and re-materialising equals the incremental
    result — including payload triples hanging off the clique."""
    from repro.data.datasets import clique_with_spokes

    facts, prog, dic = clique_with_spokes(7, 4)
    sa_rows = np.flatnonzero(facts[:, 1] == dic.id_of("owl:sameAs"))
    rng = np.random.default_rng(42)
    subsets = [np.zeros(0, np.int64), sa_rows]  # edge cases first
    for _ in range(6):
        m = int(rng.integers(1, sa_rows.shape[0] + 1))
        subsets.append(rng.choice(sa_rows, size=m, replace=False))
    for sub in subsets:
        state = materialise_incremental(facts, prog, dic.n_resources)
        delta = facts[np.asarray(sub, dtype=np.int64)]
        delete_facts(state, delta)
        remaining = (
            facts[~np.isin(pack(facts), pack(delta))] if delta.shape[0] else facts
        )
        assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_add_then_delete_roundtrip():
    """add(D); delete(D) returns to the original materialisation."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20, hierarchy_depth=1
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    rep_before = state.rep.copy()
    idp = dic.id_of(":idProp")
    # a bridge edge that merges two previously-distinct cliques
    g0 = facts[facts[:, 1] == idp][0, 0]
    g1 = facts[facts[:, 1] == idp][-1, 0]
    vid = dic.intern(":bridge")
    bridge = np.asarray(
        [[g0, idp, vid], [g1, idp, vid]], np.int32
    )
    add_facts(state, bridge)
    assert _packset(state.triples()) != before  # the merge happened
    delete_facts(state, bridge)
    assert _packset(state.triples()) == before
    assert (state.rep[: rep_before.shape[0]] == rep_before).all()


# ---------------------------------------------------------------------------
# generated update streams (the acceptance-criteria oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "gen_kw, seed",
    [
        (dict(n_groups=3, group_size=3, n_spokes_per=2, n_plain=40,
              hierarchy_depth=2), 0),
        (dict(n_groups=2, group_size=4, n_spokes_per=1, n_plain=30,
              hierarchy_depth=1, chain_rules=True), 1),
        (dict(n_groups=4, group_size=3, n_spokes_per=2, n_plain=25,
              hierarchy_depth=2, hometown_groups=1, hometown_size=5), 2),
    ],
    ids=["claros_ish", "chains_ish", "uobm_ish"],
)
def test_update_streams_match_scratch(gen_kw, seed):
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(
        facts, dic, n_events=5, batch=10, seed=seed
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    explicit = facts
    for op, delta in events:
        explicit = _explicit_apply(explicit, op, delta)
        if op == "add":
            add_facts(state, delta)
        else:
            delete_facts(state, delta)
        assert_matches_scratch(state, explicit, prog, dic.n_resources)


def test_delete_then_readd_in_one_stream():
    """delete(D); add(D) inside one stream restores store and rho exactly."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20, hierarchy_depth=1
    )
    state = materialise_incremental(facts, prog, dic.n_resources)
    before = _packset(state.triples())
    rep_before = state.rep.copy()
    idp = dic.id_of(":idProp")
    delta = facts[np.flatnonzero(facts[:, 1] == idp)[:3]]
    delete_facts(state, delta)
    assert _packset(state.triples()) != before  # the split happened
    add_facts(state, delta)
    assert _packset(state.triples()) == before
    assert (state.rep == rep_before).all()
    assert_matches_scratch(state, facts, prog, dic.n_resources)


# ---------------------------------------------------------------------------
# differential fuzz harness: sharded incremental vs from-scratch oracle
# ---------------------------------------------------------------------------

def _run_differential_stream(gen_kw, seed, n_events, batch, engine=True):
    """Apply a sampled update stream and assert oracle equality per batch.

    ``engine=True`` drives the sharded device path
    (:meth:`JaxEngine.add_facts` / ``delete_facts``); ``engine=False`` the
    host reference subsystem.  Either way the result after EVERY batch must
    equal from-scratch ``materialise_rew`` on the updated explicit set.
    """
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(
        facts, dic, n_events=n_events, batch=batch, seed=seed
    )
    stream_desc = [(op, delta.shape[0]) for op, delta in events]
    explicit = facts
    if engine:
        from repro.core.engine_jax import JaxEngine

        eng = JaxEngine(
            dic.n_resources, capacity=1 << 11, bind_cap=1 << 11,
            out_cap=1 << 11, rewrite_cap=1 << 11,
        )
        state = eng.materialise_state(facts, prog)
        for i, (op, delta) in enumerate(events):
            explicit = _explicit_apply(explicit, op, delta)
            (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
            ref = materialise_rew(explicit, prog, dic.n_resources)
            got, want = _packset(eng.state_triples(state)), _packset(ref.triples())
            assert got == want, (
                f"store diverged after event {i} of {stream_desc}: "
                f"+{len(got - want)}/-{len(want - got)} triples"
            )
            rep = eng.state_rep(state)
            assert (rep[: ref.rep.shape[0]] == ref.rep).all(), (
                f"rho diverged after event {i} of {stream_desc}"
            )
            tail = rep[ref.rep.shape[0]:]
            assert (tail == np.arange(ref.rep.shape[0], rep.shape[0])).all()
    else:
        state = materialise_incremental(facts, prog, dic.n_resources)
        for i, (op, delta) in enumerate(events):
            explicit = _explicit_apply(explicit, op, delta)
            (add_facts if op == "add" else delete_facts)(state, delta)
            assert_matches_scratch(state, explicit, prog, dic.n_resources)


_FUZZ_COMBOS = [
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=15,
          hierarchy_depth=1), 7, 4, 8, True),
    (dict(n_groups=1, group_size=4, n_spokes_per=2, n_plain=5,
          hierarchy_depth=0), 11, 5, 6, True),
    (dict(n_groups=3, group_size=2, n_spokes_per=1, n_plain=25,
          hierarchy_depth=2, chain_rules=True), 13, 4, 10, False),
    (dict(n_groups=2, group_size=3, n_spokes_per=2, n_plain=20,
          hierarchy_depth=1, hometown_groups=1, hometown_size=4), 17, 5, 8,
     False),
]


@pytest.mark.parametrize(
    "gen_kw, seed, n_events, batch, engine", _FUZZ_COMBOS,
    ids=["eng_basic", "eng_dense", "host_chains", "host_hometown"],
)
def test_fuzz_fallback_streams(gen_kw, seed, n_events, batch, engine):
    """Seeded differential fuzz that runs without hypothesis installed."""
    _run_differential_stream(gen_kw, seed, n_events, batch, engine=engine)


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without the test extra: fallback fuzz only
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _stream_params = given(
        seed=st.integers(0, 2**16),
        n_events=st.integers(1, 5),
        batch=st.integers(2, 12),
        n_groups=st.integers(1, 3),
        group_size=st.integers(2, 4),
        n_plain=st.integers(0, 25),
        hierarchy_depth=st.integers(0, 2),
    )
    _fuzz_settings = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )

    def _fuzz_body(seed, n_events, batch, n_groups, group_size, n_plain,
                   hierarchy_depth):
        gen_kw = dict(
            n_groups=n_groups, group_size=group_size, n_spokes_per=1,
            n_plain=n_plain, hierarchy_depth=hierarchy_depth,
        )
        _run_differential_stream(gen_kw, seed, n_events, batch, engine=True)

    # quick budget for tier-1; hypothesis shrinks a failing case to a
    # minimal stream (fewest events, smallest batches, tiniest graph)
    test_fuzz_update_stream_differential = _stream_params(
        settings(max_examples=10, **_fuzz_settings)(_fuzz_body)
    )

    # nightly tier: larger example budget, deselectable via -m "not slow"
    test_fuzz_update_stream_differential_nightly = pytest.mark.slow(
        _stream_params(settings(max_examples=100, **_fuzz_settings)(_fuzz_body))
    )


# ---------------------------------------------------------------------------
# kernel-batched normal forms + engine integration
# ---------------------------------------------------------------------------

def test_normal_forms_kernel_parity():
    rng = np.random.default_rng(0)
    rep = np.arange(300, dtype=np.int32)
    rep[rng.integers(0, 300, size=60)] = rng.integers(0, 50, size=60)
    from repro.core.uf import compress_np

    rep = compress_np(rep)
    spo = rng.integers(0, 300, size=(200, 3)).astype(np.int32)
    np_out = normal_forms(spo, rep, use_kernel=False)
    k_out = normal_forms(spo, rep, use_kernel=True)
    assert (np_out == k_out).all()


def test_rewrite_owner_kernel_parity():
    """Fused (normal form, owner shard) matches the numpy route keys."""
    from repro.core.uf import compress_np
    from repro.kernels.rewrite_triples import rewrite_owner

    rng = np.random.default_rng(1)
    rep = np.arange(300, dtype=np.int32)
    rep[rng.integers(0, 300, size=60)] = rng.integers(0, 50, size=60)
    rep = compress_np(rep)
    spo = rng.integers(0, 300, size=(200, 3)).astype(np.int32)
    for n_shards in (1, 4):
        nf, owner = rewrite_owner(spo, rep, n_shards)
        assert (np.asarray(nf) == rep[spo]).all()
        assert (np.asarray(owner) == rep[spo][:, 0] % n_shards).all()


def test_delete_with_kernel_normal_forms():
    facts, prog, dic = single_clique(5)
    state = materialise_incremental(
        facts, prog, dic.n_resources, use_kernel=True
    )
    delete_facts(state, facts[1:2])
    remaining = np.concatenate([facts[:1], facts[2:]], axis=0)
    assert_matches_scratch(state, remaining, prog, dic.n_resources)


def test_engine_materialise_incremental():
    from repro.core.engine_jax import JaxEngine

    facts, prog, dic = pex()
    updates = [
        ("add", np.asarray([[facts[0, 0], facts[0, 1], facts[2, 2]]], np.int32)),
        ("delete", facts[1:2]),
    ]
    eng = JaxEngine(
        dic.n_resources, capacity=256, bind_cap=256, out_cap=256, rewrite_cap=256
    )
    spo, rep, stats = eng.materialise_incremental(facts, prog, updates)

    explicit = facts
    for op, delta in updates:
        explicit = _explicit_apply(explicit, op, delta)
    ref = materialise_rew(explicit, prog, dic.n_resources)
    assert _packset(spo) == _packset(ref.triples())
    assert (rep == ref.rep).all()
