"""Closed-form claims of paper §3 about the AX-mode blowup.

Paper: for an owl:sameAs-clique of size n, rules ~=1..~=4 derive n^2 sameAs
triples via 2n^3 + n^2 + n derivations; each triple <s,p,o> with terms in
cliques of sizes (n_s, n_p, n_o) expands to n_s*n_p*n_o copies, each derived
n_s + n_p + n_o times.

Our engine counts a derivation per (rule, substitution) pair for *all* rules
including the three ~=1 instances, so the clique closed form differs from the
paper's in the sub-cubic terms (the paper books ~=1 once per distinct
reflexive fact): ours is exactly 2n^3 + 4n^2 + 6.  The cubic term — the claim
that matters — matches the paper exactly, as does the per-copy count
n_s + n_p + n_o (which involves no ~=1 accounting).
"""

import numpy as np
import pytest

from repro.core.materialise import materialise, materialise_ax
from repro.core.terms import SAME_AS
from repro.core.triples import pack
from repro.data.datasets import clique_with_spokes, single_clique


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6])
def test_clique_sameas_triples_quadratic(n):
    facts, prog, dic = single_clique(n)
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    t = ax.triples()
    sa = t[t[:, 1] == SAME_AS]
    clique_sa = sa[sa[:, 0] != SAME_AS]  # exclude <sameAs,sameAs,sameAs>
    assert clique_sa.shape[0] == n * n


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6])
def test_clique_derivations_cubic(n):
    facts, prog, dic = single_clique(n)
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    # our exact closed form; cubic term 2n^3 as in the paper
    assert ax.stats.derivations == 2 * n**3 + 4 * n**2 + 6


@pytest.mark.parametrize("n", [2, 3, 4, 5])
def test_rew_eliminates_cubic_blowup(n):
    facts, prog, dic = single_clique(n)
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    # REW: n-1 merges, a handful of reflexive facts, zero joins over cliques.
    assert rew.stats.merged_resources == n - 1
    assert rew.stats.derivations <= 2 * n + 6  # linear, not cubic
    t = rew.triples()
    sa = t[t[:, 1] == SAME_AS]
    assert (sa[:, 0] == sa[:, 2]).all()


@pytest.mark.parametrize("n,k", [(2, 3), (3, 2), (4, 1), (3, 5)])
def test_spoke_copy_expansion_exact(n, k):
    """Each spoke triple <s_j, :spoke, c_0> has clique sizes (1, 1, n):
    AX materialises exactly n copies, each derived exactly 1+1+n times."""
    facts, prog, dic = clique_with_spokes(n, k)
    ax = materialise_ax(facts, prog, dic.n_resources, track_derivations=True)
    t = ax.triples()
    spoke = dic.id_of(":spoke")
    spoke_triples = t[t[:, 1] == spoke]
    assert spoke_triples.shape[0] == n * k  # n_s * n_p * n_o copies per spoke
    keys = pack(spoke_triples)
    for key in keys.tolist():
        assert ax.deriv_counter[key] == 1 + 1 + n  # n_s + n_p + n_o


def test_factor_report_shape():
    facts, prog, dic = single_clique(5)
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    f = rew.stats.factor_over(ax.stats)
    assert f["derivations"] > 5.0  # rewriting wins by a lot even at n=5
    assert f["triples"] > 1.0
