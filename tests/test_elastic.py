"""Elastic re-mesh restore: a checkpoint written under one mesh restores
onto a different device count/sharding (subprocess with 8 fake devices)."""

import json
import subprocess
import sys
import textwrap

PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    sys.path.insert(0, "%(src)s")
    from repro.ckpt import save_checkpoint, restore_checkpoint
    from repro.launch.mesh import make_mesh

    d = sys.argv[1]
    mesh8 = make_mesh((8,), ("data",))
    mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])

    tree = {
        "w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
        "emb": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
    }
    sh8 = {
        "w": NamedSharding(mesh8, P("data", None)),
        "emb": NamedSharding(mesh8, P("data", None)),
    }
    placed = jax.device_put(tree, sh8)
    assert len(placed["w"].sharding.device_set) == 8
    save_checkpoint(d, 5, placed, aux={"next_step": 5})

    # restore onto the SMALLER mesh (elastic shrink)
    sh2 = {
        "w": NamedSharding(mesh2, P("data", None)),
        "emb": NamedSharding(mesh2, P(None, "data")),
    }
    out, aux, step = restore_checkpoint(d, tree, shardings=sh2)
    assert step == 5
    assert len(out["w"].sharding.device_set) == 2
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["emb"]), np.asarray(tree["emb"]))
    print(json.dumps({"ok": True}))
    """
)


def test_elastic_restore_across_meshes(tmp_path):
    prog = PROG % {"src": "src"}
    proc = subprocess.run(
        [sys.executable, "-c", prog, str(tmp_path)],
        capture_output=True, text=True, timeout=300, cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
