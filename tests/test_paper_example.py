"""The paper's running example (§3, §4 Table 1) reproduced exactly."""

import numpy as np

from repro.core.materialise import check_theorem1, expand, materialise
from repro.core.terms import SAME_AS
from repro.data.datasets import pex, pex_rule_rewrite


def test_pex_rew_final_store():
    """After REW materialisation of P_ex the unmarked store is exactly the
    paper's end state: one presidentOf fact + reflexive sameAs facts, with
    {USA,US,America} and {Obama,USPresident} merged."""
    facts, prog, dic = pex()
    rew = materialise(facts, prog, dic.n_resources, mode="REW")

    usa, us, am = (dic.id_of(x) for x in (":USA", ":US", ":America"))
    ob, up = dic.id_of(":Obama"), dic.id_of(":USPresident")
    # cliques are correct (representative = min ID, a valid total order)
    assert rew.rep[usa] == rew.rep[us] == rew.rep[am]
    assert rew.rep[ob] == rew.rep[up]
    assert rew.rep[usa] != rew.rep[ob]
    assert rew.stats.merged_resources == 3  # paper: 3 resources rewritten

    t = {tuple(map(int, r)) for r in rew.triples()}
    pres = dic.id_of(":presidentOf")
    r_usa, r_ob = int(rew.rep[usa]), int(rew.rep[ob])
    expected = {
        (r_ob, pres, r_usa),
        (r_ob, SAME_AS, r_ob),
        (r_usa, SAME_AS, r_usa),
        (pres, SAME_AS, pres),
        (SAME_AS, SAME_AS, SAME_AS),
    }
    assert t == expected


def test_pex_derivation_counts():
    """Paper §4: REW makes ~6 derivations on P_ex 'instead of more than 60'.

    The exact count depends on the representative-choice path: the paper's
    trace picks :US (forcing rule rewriting and one R-queue re-derivation,
    6 total); our min-ID order picks :USA (no rule change, 5 total; the
    rewrite-forcing variant below makes 7 because both rules are re-run).
    The claim being reproduced is the order of magnitude: single digits vs
    the >60 of the axiomatisation.  Reflexive additions (Algorithm 4 lines
    17-18) are counted separately by our stats.
    """
    facts, prog, dic = pex()
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    rule_derivs = rew.stats.derivations - rew.stats.reflexive_added
    assert rule_derivs == 5  # deterministic for min-ID representatives
    assert ax.stats.derivations > 60
    assert ax.stats.derivations > 10 * rew.stats.derivations

    facts, prog, dic = pex_rule_rewrite()
    rew_rr = materialise(facts, prog, dic.n_resources, mode="REW")
    assert rew_rr.stats.derivations - rew_rr.stats.reflexive_added == 7


def test_pex_theorem1_and_expansion():
    facts, prog, dic = pex()
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    check_theorem1(rew, ax)
    # spot-check the expansion contains all 9 sameAs pairs of the USA-clique
    usa, us, am = (dic.id_of(x) for x in (":USA", ":US", ":America"))
    exp = expand(rew.triples(), rew.rep)
    for a in (usa, us, am):
        for b in (usa, us, am):
            assert (a, SAME_AS, b) in exp


def test_pex_marked_triples_kept():
    """Mark-don't-delete: the arena retains outdated rows (paper §4)."""
    facts, prog, dic = pex()
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    assert rew.stats.triples_total > rew.stats.triples_unmarked


def test_rule_rewriting_required_for_completeness():
    """§3: 'rewriting only triples can be insufficient' — when :US is chosen
    as representative, rule (S) with constant :USA only fires after rule
    rewriting.  Without it, <USPresident sameAs Obama> would be lost."""
    facts, prog, dic = pex_rule_rewrite()
    rew = materialise(facts, prog, dic.n_resources, mode="REW")
    ax = materialise(facts, prog, dic.n_resources, mode="AX")
    # the dangerous representative choice actually happened
    usa, us = dic.id_of(":USA"), dic.id_of(":US")
    assert rew.rep[usa] == us
    # rule rewriting fired
    assert rew.stats.rules_requeued > 0
    # and completeness held anyway
    ob, up = dic.id_of(":Obama"), dic.id_of(":USPresident")
    assert rew.rep[ob] == rew.rep[up]
    check_theorem1(rew, ax)
