"""Async serving tier: batched execution, threaded scheduler, snapshots.

Three differential contracts on top of tests/test_serve_triple_store.py's
oracle (docs/serving.md):

  * **batched == scalar == oracle** — the vmapped shape-grouped executor
    (:mod:`repro.sparql.batched`) must return bag-identical answers to the
    scalar host path (:func:`repro.sparql.executor.evaluate_at`) and to the
    from-scratch REW materialisation, at every epoch, across workload
    profiles and BGP shapes (including non-batchable shapes that fall back
    to the host path);
  * **threaded == cooperative** — the same seeded interleaved trace driven
    through a ``threaded=True`` store (maintenance on the worker thread,
    reads racing it from the caller) must land every answer on the oracle
    at its reported epoch and end at the same final fixpoint as the
    deterministic cooperative scheduler;
  * **device snapshot == host snapshot** — ``publish_snapshot``'s
    device-resident sorted orders must describe exactly the rows
    ``read_snapshot`` copies to host.

Plus unit coverage for the incremental :meth:`FrozenRho.refreshed`
publication step, the store's dispatch audit staying clean under a mixed
batched workload, and the pure ``compare_serve`` bench gate.
"""

import numpy as np
import pytest

from repro.core.engine_jax import JaxEngine
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op, pack
from repro.core.uf import FrozenRho
from repro.data.generator import generate, sample_update_stream
from repro.serve.triple_store import TripleStore
from repro.sparql import Query, evaluate
from repro.sparql.batched import BatchedExecutor, build_plan, shape_signature
from repro.sparql.executor import evaluate_at


def _engine(dic, cap=1 << 11):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap,
    )


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


_PROFILES = [
    ("chain_like", dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=20,
                        hierarchy_depth=1, chain_rules=True), 3),
    ("clique_like", dict(n_groups=2, group_size=5, n_spokes_per=2, n_plain=10,
                         hierarchy_depth=1), 5),
    ("dbpedia_like", dict(n_groups=2, group_size=3, n_spokes_per=2, n_plain=60,
                          hierarchy_depth=2, chain_rules=True), 7),
]


def _mixed_queries(facts, dic, n, seed):
    """Generator shapes plus hand-built shapes the generator never emits:
    a const-subject probe and an all-var atom (non-batchable: no bound
    prefix in either key order -> host fallback)."""
    qs = [
        payload
        for _op, payload in sample_update_stream(
            facts, dic, n_events=n, batch=4, p_query=1.0, seed=seed
        )
    ]
    s0, p0 = int(facts[0, 0]), int(facts[0, 1])
    qs.append(Query([(s0, p0, -1)], [], [-1], False))
    qs.append(Query([(-1, -2, -3)], [], [-1, -2], False))
    return qs


# ---------------------------------------------------------------------------
# batched == scalar == from-scratch oracle, at every epoch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "gen_kw, seed", [(kw, s) for _n, kw, s in _PROFILES],
    ids=[n for n, _kw, _s in _PROFILES],
)
def test_batched_matches_scalar_and_oracle_per_epoch(gen_kw, seed):
    facts, prog, dic = generate(**gen_kw, seed=seed)
    updates = sample_update_stream(facts, dic, n_events=3, batch=6, seed=seed)
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    bx = store._batched
    queries = _mixed_queries(facts, dic, n=8, seed=seed + 1)

    explicit = np.asarray(facts, np.int32)
    for epoch_ops in [None] + updates:  # epoch 0, then one epoch per update
        if epoch_ops is not None:
            op, delta = epoch_ops
            store.submit_update(op, delta)
            store.drain()
            explicit = apply_op(explicit, op, delta)
        snap = store.snapshot
        ref = materialise_rew(explicit, prog, dic.n_resources)
        batched = bx.run(queries, snap, dic)
        for q, (ans, ep) in zip(queries, batched):
            assert ep == snap.epoch
            assert (ans, ep) == evaluate_at(q, snap, dic), (
                f"batched != scalar at epoch {snap.epoch} for {q.patterns}"
            )
            assert ans == evaluate(q, ref.triples(), ref.rep, dic), (
                f"batched != oracle at epoch {snap.epoch} for {q.patterns}"
            )
    # the mixed list exercised BOTH paths: vmapped groups and host fallback
    assert bx.stats["batched"] > 0 and bx.stats["fallback"] > 0


def test_non_batchable_and_short_groups_fall_back():
    facts, prog, dic = generate(
        n_groups=1, group_size=3, n_spokes_per=1, n_plain=10,
        hierarchy_depth=0, seed=0,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    bx = store._batched
    # all-var atom: no bound prefix in either order -> no plan
    sig, _ = shape_signature(Query([(-1, -2, -3)], [], [-1], False).patterns)
    assert build_plan(sig) is None
    # a singleton group sits below min_batch -> scalar path, still correct
    p0 = int(facts[0, 1])
    q = Query([(-1, p0, -2)], [], [-1], False)
    before = bx.stats["batched"]
    (got,) = bx.run([q], store.snapshot, dic)
    assert bx.stats["batched"] == before  # stayed on the host path
    assert got == evaluate_at(q, store.snapshot, dic)


def test_batched_overflow_falls_back_to_host():
    """A per-query expansion wider than the vmap width must flag overflow
    and be recomputed on the host path — never silently truncated."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=2, n_plain=60,
        hierarchy_depth=1, seed=1,
    )
    store = TripleStore(
        facts, prog, dic, engine=_engine(dic), query_width=4, min_batch=2
    )
    bx = store._batched
    assert bx.width == 4
    ps = np.unique(np.asarray(facts)[:, 1])
    qs = [Query([(-1, int(p), -2)], [], [-1, -2], False) for p in ps[:4]]
    got = bx.run(qs, store.snapshot, dic)
    assert bx.stats["overflow"] > 0
    for q, g in zip(qs, got):
        assert g == evaluate_at(q, store.snapshot, dic)


# ---------------------------------------------------------------------------
# threaded scheduler == cooperative scheduler == oracle
# ---------------------------------------------------------------------------

def test_threaded_trace_matches_oracle_and_cooperative():
    gen_kw = dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=20,
                  hierarchy_depth=1)
    seed = 11
    facts, prog, dic = generate(**gen_kw, seed=seed)
    trace = sample_update_stream(
        facts, dic, n_events=8, batch=6, p_query=0.5, seed=seed
    )

    # threaded: maintenance on the worker, reads racing it from this thread
    store_t = TripleStore(facts, prog, dic, engine=_engine(dic), threaded=True)
    rng = np.random.default_rng(seed)
    updates, queries = [], []
    try:
        for op, payload in trace:
            if op == "query":
                queries.append(store_t.submit_query(payload))
            else:
                updates.append(store_t.submit_update(op, payload))
            if rng.random() < 0.6:  # race reads against in-flight epochs
                store_t._drain_queries()
        store_t.drain()
        assert all(t.status == "done" for t in updates + queries)
        assert store_t.epoch == len(updates)

        # every answer must sit on the from-scratch oracle at its epoch
        explicit_at = {0: np.asarray(facts, np.int32)}
        for t in sorted(updates, key=lambda t: t.epoch):
            explicit_at[t.epoch] = apply_op(
                explicit_at[t.epoch - 1], t.op, t.delta
            )
        mats = {}

        def mat(e):
            if e not in mats:
                mats[e] = materialise_rew(
                    explicit_at[e], prog, dic.n_resources
                )
            return mats[e]

        for t in queries:
            ref = mat(t.epoch)
            assert t.answer == evaluate(t.query, ref.triples(), ref.rep, dic)

        # and the final fixpoint must equal the cooperative scheduler's
        store_c = TripleStore(facts, prog, dic, engine=_engine(dic))
        for op, payload in trace:
            if op == "query":
                store_c.submit_query(payload)
            else:
                store_c.submit_update(op, payload)
        store_c.drain()
        assert store_c.epoch == store_t.epoch
        assert _packset(store_t.snapshot.triples) == _packset(
            store_c.snapshot.triples
        )
        n = min(store_t.snapshot.rho.rep.shape[0],
                store_c.snapshot.rho.rep.shape[0])
        assert (store_t.snapshot.rho.rep[:n]
                == store_c.snapshot.rho.rep[:n]).all()
    finally:
        store_t.close()


def test_threaded_step_forbidden_and_close_idempotent():
    facts, prog, dic = generate(
        n_groups=1, group_size=3, n_spokes_per=1, n_plain=5,
        hierarchy_depth=0, seed=0,
    )
    with TripleStore(facts, prog, dic, engine=_engine(dic), threaded=True) as s:
        with pytest.raises(RuntimeError):
            s.step()
        t = s.submit_update("delete", facts[:1])
        s.drain()
        assert t.status == "done" and s.epoch == 1
    s.close()  # second close is a no-op


def test_threaded_failed_update_surfaces_on_caller():
    facts, prog, dic = generate(
        n_groups=1, group_size=3, n_spokes_per=1, n_plain=5,
        hierarchy_depth=0, seed=0,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic), threaded=True)
    try:
        orig, tripped = store._make_gen, []

        def boom(t):
            if not tripped:
                tripped.append(True)
                raise RuntimeError("injected maintenance failure")
            return orig(t)

        store._make_gen = boom
        t = store.submit_update("delete", facts[:1])
        with pytest.raises(RuntimeError, match="injected"):
            store.drain()
        assert t.status == "failed"
        t2 = store.submit_update("delete", facts[:1])  # worker survived
        store.drain()
        assert t2.status == "done" and store.epoch == 1
    finally:
        store.close()


# ---------------------------------------------------------------------------
# snapshot layer: device-resident publication
# ---------------------------------------------------------------------------

def test_publish_snapshot_matches_read_snapshot():
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=2, n_plain=40,
        hierarchy_depth=1, seed=5,
    )
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    dev = eng.publish_snapshot(state)
    host = eng.read_snapshot(state)
    assert dev.epoch == host.epoch
    assert dev.on_device
    assert _packset(dev.triples) == _packset(host.triples)
    assert (dev.rho.rep == host.rho.rep).all()
    # both device orders are genuinely sorted over the live prefix
    keys = np.asarray(dev.d_keys)[: dev.n_live]
    pos = np.asarray(dev.d_keys_pos)[: dev.n_live]
    assert (np.diff(keys) >= 0).all() and (np.diff(pos) >= 0).all()
    # and describe the same row set
    tri_pos = np.asarray(dev.d_triples_pos)[: dev.n_live]
    assert _packset(tri_pos) == _packset(dev.triples)


def test_double_buffering_old_snapshot_survives_republication():
    facts, prog, dic = generate(
        n_groups=1, group_size=4, n_spokes_per=2, n_plain=10,
        hierarchy_depth=0, seed=2,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    snap0 = store.snapshot
    before = _packset(snap0.triples)
    k0 = np.asarray(snap0.d_keys).copy()
    store.submit_update("delete", facts[:1])
    store.drain()
    snap1 = store.snapshot
    assert snap1 is not snap0 and snap1.epoch == snap0.epoch + 1
    # the retired buffer generation is untouched by the new publication
    assert _packset(snap0.triples) == before
    assert (np.asarray(snap0.d_keys) == k0).all()
    assert _packset(snap1.triples) != before


def test_frozen_rho_refreshed():
    rep = np.arange(10, dtype=np.int32)
    rep[3] = 1
    rep[4] = 1  # clique {1, 3, 4}
    r0 = FrozenRho(rep)
    assert sorted(r0.members[1].tolist()) == [1, 3, 4]

    # unchanged rep -> the very same object (cached tables carry over)
    assert r0.refreshed(rep.copy()) is r0

    # merge clique {1,3,4} with {7}: only the affected clique recomputes,
    # untouched member arrays carry over by reference
    rep2 = rep.copy()
    rep2[7] = 1
    r1 = r0.refreshed(rep2)
    assert r1 is not r0
    assert sorted(r1.members[1].tolist()) == [1, 3, 4, 7]
    scratch = FrozenRho(rep2)
    assert {k: v.tolist() for k, v in r1.members.items()} \
        == {k: v.tolist() for k, v in scratch.members.items()}
    assert not r1.rep.flags.writeable

    # split: drop 4 from the clique; stale member arrays must not linger
    rep3 = rep2.copy()
    rep3[4] = 4
    r2 = r1.refreshed(rep3)
    assert sorted(r2.members[1].tolist()) == [1, 3, 7]
    assert 4 not in r2.members

    # interned tail: new resources merged straight into an old clique
    rep4 = np.concatenate([rep3, np.asarray([1, 11], np.int32)])
    r3 = r2.refreshed(rep4)
    assert sorted(r3.members[1].tolist()) == [1, 3, 7, 10]
    scratch4 = FrozenRho(rep4)
    assert {k: v.tolist() for k, v in r3.members.items()} \
        == {k: v.tolist() for k, v in scratch4.members.items()}

    # a view whose members were never materialised rebuilds from scratch
    r_cold = FrozenRho(rep)
    assert sorted(r_cold.refreshed(rep2).members[1].tolist()) == [1, 3, 4, 7]


# ---------------------------------------------------------------------------
# dispatch audit stays clean under the mixed batched workload
# ---------------------------------------------------------------------------

def test_store_audit_clean_after_mixed_batched_workload():
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=20,
        hierarchy_depth=1, seed=3,
    )
    store = TripleStore(facts, prog, dic, engine=_engine(dic))
    queries = _mixed_queries(facts, dic, n=6, seed=4)
    for q in queries:
        store.submit_query(q)
    store.drain()
    for op, delta in sample_update_stream(
        facts, dic, n_events=2, batch=4, seed=3
    ):
        store.submit_update(op, delta)
        for q in queries[:3]:
            store.submit_query(q)
        store.drain()
    assert store._batched.stats["batched"] > 0
    assert store.audit() == []
    by_phase = store.engine.dispatches.by_phase
    assert any(ph == "query" for ph, _fam in by_phase)
    assert any(ph == "publish" for ph, _fam in by_phase)


# ---------------------------------------------------------------------------
# the pure compare_serve bench gate
# ---------------------------------------------------------------------------

def _serve_row(**over):
    row = {
        "dataset": "dbpedia_like",
        "busy_over_idle": 1.05,
        "batched_speedup": 4.2,
        "audit_problems": [],
        "closed_loop": {"updates_submitted": 4, "epochs_completed": 4},
    }
    row.update(over)
    return row


def test_compare_serve_gate():
    from benchmarks.run import compare_serve

    assert compare_serve([_serve_row()]) == []
    # busy reads paying maintenance cost
    assert any(
        "busy_over_idle" in p
        for p in compare_serve([_serve_row(busy_over_idle=1.7)])
    )
    # batched drain below the floor, but only on the pinned profile
    assert any(
        "batched_speedup" in p
        for p in compare_serve([_serve_row(batched_speedup=2.0)])
    )
    assert compare_serve(
        [_serve_row(), _serve_row(dataset="chain_like", batched_speedup=0.5)]
    ) == []
    # dropping the pinned profile must not read as a pass
    assert any(
        "missing" in p
        for p in compare_serve([_serve_row(dataset="chain_like")])
    )
    # a dirty embedded audit fails the row
    assert any(
        "audit" in p
        for p in compare_serve([_serve_row(audit_problems=["boom"])])
    )
    # a closed loop whose worker never completed an epoch measured idle air
    assert any(
        "closed_loop" in p
        for p in compare_serve(
            [_serve_row(closed_loop={"updates_submitted": 4,
                                     "epochs_completed": 0})]
        )
    )
    # missing fields fail loudly rather than passing silently
    row = _serve_row()
    del row["busy_over_idle"]
    assert any("busy_over_idle" in p for p in compare_serve([row]))
