"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n,v", [(7, 13), (100, 100), (513, 1000), (2000, 257)])
@pytest.mark.parametrize("block,tile", [(128, 128), (512, 512), (64, 256)])
def test_pointer_jump_sweep(n, v, block, tile):
    idx = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    table = jnp.asarray(RNG.integers(0, 1 << 20, v), jnp.int32)
    out = ops.pointer_jump(idx, table, block=block, tile=tile)
    np.testing.assert_array_equal(out, ref.pointer_jump_ref(idx, table))


@pytest.mark.parametrize("n,v", [(5, 9), (300, 512), (1025, 700)])
def test_rewrite_triples_sweep(n, v):
    spo = jnp.asarray(RNG.integers(0, v, (n, 3)), jnp.int32)
    rho = jnp.asarray(np.arange(v), jnp.int32)
    # merge ~30% of resources
    merges = RNG.integers(0, v, v // 3)
    rho = rho.at[merges].set(jnp.asarray(RNG.integers(0, v, v // 3), jnp.int32))
    out, changed = ops.rewrite_triples(spo, rho)
    ref_out, ref_changed = ref.rewrite_triples_ref(spo, rho)
    np.testing.assert_array_equal(out, ref_out)
    np.testing.assert_array_equal(changed, ref_changed)


@pytest.mark.parametrize("nq,nk", [(10, 64), (257, 1000), (1000, 3)])
@pytest.mark.parametrize("big", [False, True])
def test_search_bounds_sweep(nq, nk, big):
    hi_bits = 62 if big else 20  # exercise >32-bit keys (the packed-key case)
    keys = np.sort(RNG.integers(0, 1 << hi_bits, nk).astype(np.int64))
    queries = np.concatenate(
        [RNG.choice(keys, nq // 2), RNG.integers(0, 1 << hi_bits, nq - nq // 2)]
    ).astype(np.int64)
    lo, hi = ops.search_bounds(queries, keys)
    rlo, rhi = ref.search_bounds_ref(queries, keys)
    np.testing.assert_array_equal(lo, rlo)
    np.testing.assert_array_equal(hi, rhi)


@pytest.mark.parametrize("nq,nk", [(9, 50), (300, 1000)])
@pytest.mark.parametrize("k", [1, 2, 3])
def test_prefix_range_bounds_sweep(nq, nk, k):
    """The bound-head probe of the targeted rederive join: a length-k
    (s, p, o) prefix matches one contiguous range of the sorted packed-key
    column.  IDs are drawn small so ranges are frequently non-empty."""
    ids = RNG.integers(0, 12, (nk, 3)).astype(np.int64)
    keys = np.sort((ids[:, 0] << 42) | (ids[:, 1] << 21) | ids[:, 2])
    prefixes = RNG.integers(0, 14, (nq, k)).astype(np.int32)
    start, end = ops.prefix_range_bounds(prefixes, keys)
    rstart, rend = ref.prefix_range_bounds_ref(prefixes, keys)
    np.testing.assert_array_equal(start, rstart)
    np.testing.assert_array_equal(end, rend)
    assert (end >= start).all()
    # spot-check: every row inside a range actually carries the prefix
    shift = 21 * (3 - k)
    packed_pref = np.zeros(nq, np.int64)
    for j in range(k):
        packed_pref = (packed_pref << 21) | prefixes[:, j]
    for i in range(min(nq, 32)):
        rows = keys[start[i]:end[i]]
        assert ((rows >> shift) == packed_pref[i]).all()


@pytest.mark.parametrize(
    "n,block,tile", [(7, 128, 128), (128, 128, 128), (513, 128, 256), (1000, 64, 128)]
)
@pytest.mark.parametrize("dup", [0.0, 0.5, 1.0])
def test_dedup_order_sweep(n, block, tile, dup):
    """Stable rank permutation == jnp.argsort(stable) over packed keys with
    duplicates and KEY_MAX padding slots (the delta-stream dedup shape).

    Unlike the other int64 kernels, dedup_order is called INSIDE traced
    engine code (the fused round loop), so it takes traced int64 keys under
    the engine's x64 scope — the test mirrors that calling convention."""
    from repro.core.engine_jax import enable_x64

    keys = RNG.integers(0, 1 << 62, n).astype(np.int64)
    n_dup = int(n * dup)
    if n_dup:
        keys[RNG.integers(0, n, n_dup)] = RNG.choice(keys, n_dup)
    keys[-max(n // 8, 1):] = (1 << 63) - 1  # invalid-slot sentinels
    with enable_x64():
        order = ops.dedup_order(jnp.asarray(keys), block=block, tile=tile)
        # ranking ties by position IS argsort stability
        want = jnp.argsort(jnp.asarray(keys), stable=True)
        np.testing.assert_array_equal(order, np.asarray(want, np.int32))
    np.testing.assert_array_equal(order, ref.dedup_order_ref(keys))


@pytest.mark.parametrize("b,f,v,k", [(4, 3, 50, 8), (130, 39, 1000, 10), (64, 26, 513, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(b, f, v, k, dtype):
    ids = jnp.asarray(RNG.integers(0, v, (b, f)), jnp.int32)
    table = jnp.asarray(RNG.normal(size=(v, k)), dtype)
    out = ops.embedding_bag(ids, table)
    expected = ref.embedding_bag_ref(ids, table)
    rtol = 1e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32), rtol=rtol, atol=1e-3
    )


@pytest.mark.parametrize("b,f,k", [(3, 5, 4), (300, 39, 10), (1024, 26, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fm_interact_sweep(b, f, k, dtype):
    x = jnp.asarray(RNG.normal(size=(b, f, k)), dtype)
    out = ops.fm_interact(x)
    expected = ref.fm_interact_ref(x)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expected, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 5e-2,
        atol=1e-2,
    )


@pytest.mark.parametrize("n,s,k", [(10, 4, 8), (1000, 100, 16), (513, 700, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_sum_sweep(n, s, k, dtype):
    x = jnp.asarray(RNG.normal(size=(n, k)), dtype)
    seg = jnp.asarray(RNG.integers(0, s, n), jnp.int32)
    out = ops.segment_sum(x, seg, s)
    # oracle in f32: the kernel accumulates in f32 (preferred_element_type),
    # which is *more* precise than a bf16 jnp chain — compare to ground truth
    expected = ref.segment_sum_ref(x.astype(jnp.float32), seg, s)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expected, np.float32),
        rtol=1e-5 if dtype == jnp.float32 else 1e-1,
        atol=1e-2 if dtype == jnp.float32 else 1e-1,
    )


def test_pointer_jump_converges_like_uf():
    """Kernel-driven pointer doubling reaches the union-find fixpoint."""
    from repro.core.uf import compress_np

    v = 300
    rep = np.arange(v, dtype=np.int32)
    for a, b in RNG.integers(0, v, (40, 2)):
        ra, rb = rep[a], rep[b]
        if ra != rb:
            rep[max(ra, rb)] = min(ra, rb)
    cur = jnp.asarray(rep)
    for _ in range(12):
        cur = ops.pointer_jump(cur, cur)
    np.testing.assert_array_equal(np.asarray(cur), compress_np(rep))


# ---------------------------------------------------------------------------
# flash attention (fwd) vs the naive oracle
# ---------------------------------------------------------------------------

def _attn_inputs(b, s, t, h, kv, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, kv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("s,t,h,kv,d", [
    (64, 64, 4, 2, 32),     # GQA g=2
    (48, 48, 3, 3, 16),     # MHA, non-pow2 seq (padding path)
    (128, 128, 8, 2, 64),   # GQA g=4
    (17, 33, 2, 1, 8),      # MQA, ragged blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, t, h, kv, d, causal):
    from repro.models.layers import naive_attention

    q, k, v = _attn_inputs(2, s, t, h, kv, d, jnp.float32)
    ref_out = naive_attention(q, k, v, causal=causal)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    from repro.models.layers import naive_attention

    q, k, v = _attn_inputs(1, 32, 32, 4, 2, 32, dtype)
    ref_out = naive_attention(q, k, v, causal=True)
    out = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref_out, np.float32), atol=atol
    )


def test_flash_attention_decode_offset():
    """Single-token decode against a prefix cache: q_offset masks the tail."""
    from repro.models.layers import naive_attention

    t, pos = 64, 37
    q, k, v = _attn_inputs(2, 1, t, 4, 2, 32, jnp.float32)
    # oracle: only cache entries < pos+1 are attendable
    ref_out = naive_attention(q, k[:, : pos + 1], v[:, : pos + 1], causal=True,
                              q_offset=pos)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=pos,
                              block_q=8, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=2e-5)


def test_flash_matches_chunked_xla_path():
    """The Pallas kernel and the XLA chunked path are interchangeable."""
    from repro.models.layers import gqa_attention

    q, k, v = _attn_inputs(2, 64, 64, 4, 2, 32, jnp.float32)
    a = gqa_attention(q, k, v, causal=True, chunk=16)
    b = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("na,nb", [(16, 4), (100, 100), (1000, 37), (257, 0)])
@pytest.mark.parametrize("dup", [False, True])
def test_merge_sorted_sweep(na, nb, dup):
    """Rank-merge of two sorted key/value columns == numpy mergesort, with
    sentinel padding and (cross-column) duplicate keys."""
    from jax.experimental import enable_x64

    from repro.kernels.merge import merge_ranks, merge_sorted

    KEY_MAX = np.int64((1 << 63) - 1)
    hi = 1 << 10 if dup else 1 << 60  # force duplicates in the small space
    with enable_x64():
        a = np.sort(RNG.integers(0, hi, na).astype(np.int64))
        b = np.sort(RNG.integers(0, hi, nb).astype(np.int64))
        a[na // 2 :] = KEY_MAX  # sentinel-padded tails, like the arena index
        av = np.arange(na, dtype=np.int32)
        bv = np.arange(nb, dtype=np.int32) + 10_000
        mk, mv = merge_sorted(
            jnp.asarray(a), jnp.asarray(av), jnp.asarray(b), jnp.asarray(bv),
            out_len=na + nb,
        )
        mk, mv = np.asarray(mk), np.asarray(mv)
        assert (np.diff(mk) >= 0).all()
        np.testing.assert_array_equal(np.sort(np.concatenate([a, b])), mk)
        # every (key, val) pair survives the merge exactly once
        want = sorted(zip(a.tolist() + b.tolist(), av.tolist() + bv.tolist()))
        got = sorted(zip(mk.tolist(), mv.tolist()))
        assert want == got
        # truncation keeps a prefix of the merged order
        tk, _ = merge_sorted(
            jnp.asarray(a), jnp.asarray(av), jnp.asarray(b), jnp.asarray(bv),
            out_len=na,
        )
        np.testing.assert_array_equal(np.asarray(tk), mk[:na])
        # merge_ranks positions are a collision-free permutation
        pa, pb = merge_ranks(jnp.asarray(a), jnp.asarray(b))
        pos = np.concatenate([np.asarray(pa), np.asarray(pb)])
        assert np.array_equal(np.sort(pos), np.arange(na + nb))
