"""Paper §5: SPARQL over rewritten triples — Q1 (bag semantics) and Q2 (builtins)."""

from collections import Counter

import numpy as np
import pytest

from repro.core.materialise import materialise
from repro.data.datasets import pex
from repro.sparql import Query, evaluate, evaluate_naive


@pytest.fixture(scope="module")
def rew():
    facts, prog, dic = pex()
    res = materialise(facts, prog, dic.n_resources, mode="REW")
    return res, dic


def test_q1_bag_semantics(rew):
    """Q1 = SELECT ?x WHERE { ?x :presidentOf ?y }: each of Obama/USPresident
    must appear 3 times (once per member of the USA-clique bound to ?y)."""
    res, dic = rew
    q = Query.parse("SELECT ?x WHERE { (?x, :presidentOf, ?y) }", dic)
    ans = evaluate(q, res.triples(), res.rep, dic)
    assert ans == Counter({(":Obama",): 3, (":USPresident",): 3})


def test_q1_naive_is_wrong(rew):
    """The naive post-hoc expansion loses the multiplicities (paper §5)."""
    res, dic = rew
    q = Query.parse("SELECT ?x WHERE { (?x, :presidentOf, ?y) }", dic)
    naive = evaluate_naive(q, res.triples(), res.rep, dic)
    assert naive == Counter({(":Obama",): 1, (":USPresident",): 1})  # wrong counts


def test_q1_distinct(rew):
    res, dic = rew
    q = Query.parse("SELECT DISTINCT ?x WHERE { (?x, :presidentOf, ?y) }", dic)
    ans = evaluate(q, res.triples(), res.rep, dic)
    assert ans == Counter({(":Obama",): 1, (":USPresident",): 1})


def test_q2_builtin_expand_before_bind(rew):
    """Q2 = SELECT ?y WHERE { ?x :presidentOf :US . BIND(STR(?x) AS ?y) }:
    must produce both "Obama" and "USPresident" exactly once."""
    res, dic = rew
    q = Query.parse("SELECT ?y WHERE { (?x, :presidentOf, :US) }", dic)
    x = -1  # ?x is the first variable parsed
    y = dic.intern("?tmp-y") * 0 - 2  # fresh var id -2
    q.bind("STR", x, -2)
    q.select = [-2]
    ans = evaluate(q, res.triples(), res.rep, dic)
    assert ans == Counter({("Obama",): 1, ("USPresident",): 1})


def test_q2_naive_misses_answers(rew):
    res, dic = rew
    q = Query.parse("SELECT ?y WHERE { (?x, :presidentOf, :US) }", dic)
    q.bind("STR", -1, -2)
    q.select = [-2]
    naive = evaluate_naive(q, res.triples(), res.rep, dic)
    # the naive strategy only sees the representative's string
    assert len(naive) == 1


def test_filter_on_expanded_resources(rew):
    """FILTER(?y = :America) must match even though :America is rewritten."""
    res, dic = rew
    q = Query.parse("SELECT ?x WHERE { (?x, :presidentOf, ?y) }", dic)
    q.filter_eq(-2, dic.id_of(":America"))
    ans = evaluate(q, res.triples(), res.rep, dic)
    assert ans == Counter({(":Obama",): 1, (":USPresident",): 1})


def test_join_two_patterns(rew):
    """Two-pattern BGP across the sameAs-clique: ?x presidentOf ?y joined on ?y."""
    res, dic = rew
    q = Query.parse(
        "SELECT ?x ?z WHERE { (?x, :presidentOf, ?y) . (?z, :presidentOf, ?y) }", dic
    )
    ans = evaluate(q, res.triples(), res.rep, dic)
    # pairs (x,z) in {Obama,USPresident}^2, each x3 for the ?y clique
    assert sum(ans.values()) == 4 * 3
    assert ans[(":Obama", ":USPresident")] == 3


def test_query_over_full_expansion_equivalence(rew):
    """Ground truth: evaluating Q1 over the *expanded* store (AX semantics)
    gives the same bag as our strategy over the succinct store."""
    from repro.core.materialise import expand

    res, dic = rew
    exp = np.asarray(sorted(expand(res.triples(), res.rep)), dtype=np.int32)
    q = Query.parse("SELECT ?x WHERE { (?x, :presidentOf, ?y) }", dic)
    identity = np.arange(res.rep.shape[0], dtype=np.int32)
    over_expansion = evaluate(q, exp, identity, dic)
    over_succinct = evaluate(q, res.triples(), res.rep, dic)
    assert over_expansion == over_succinct
