"""Property-based validation of Theorem 1 on random programs and facts.

The oracle is the theorem itself: for any (E, P), the REW result must satisfy
  (1) no unmarked non-reflexive sameAs fact,
  (2) every unmarked fact is rho-normal,
  (3) expand(T, rho) == AX materialisation of (E, P).

Generation notes: owl:differentFrom is kept out of random atoms because
equating owl:sameAs with owl:differentFrom (legal in the random universe)
makes the two modes legitimately diverge on ~=5; contradictions are covered
by the deterministic tests below.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.materialise import (
    Contradiction,
    check_theorem1,
    materialise,
)
from repro.core.rules import Program, Rule
from repro.core.terms import DIFFERENT_FROM, SAME_AS

N_RES = 10  # ids 0..9; 3..9 are plain resources
CONSTS = list(range(3, N_RES))
PREDS = CONSTS + [SAME_AS]
VARS = [-1, -2, -3]

so_term = st.sampled_from(CONSTS + VARS)
pred_term = st.sampled_from(PREDS)
atom = st.tuples(so_term, pred_term, so_term)

fact = st.tuples(
    st.sampled_from(CONSTS),
    st.sampled_from(PREDS),
    st.sampled_from(CONSTS),
)


@st.composite
def rule(draw):
    body = tuple(draw(st.lists(atom, min_size=1, max_size=2)))
    body_vars = [t for a in body for t in a if t < 0]
    head_so = st.sampled_from(CONSTS + body_vars) if body_vars else st.sampled_from(CONSTS)
    head = (draw(head_so), draw(pred_term), draw(head_so))
    return Rule(head, body)


@settings(max_examples=50, deadline=None)
@given(
    facts=st.lists(fact, min_size=1, max_size=8),
    rules=st.lists(rule(), min_size=0, max_size=3),
)
def test_theorem1_random(facts, rules):
    E = np.asarray(facts, dtype=np.int32).reshape(-1, 3)
    P = Program(rules)
    ax = materialise(E, P, N_RES, mode="AX")
    rew = materialise(E, P, N_RES, mode="REW")
    check_theorem1(rew, ax)
    # rewriting must never *increase* stored triples or derivations
    assert rew.stats.triples_unmarked <= ax.stats.triples_unmarked
    assert rew.stats.derivations <= max(ax.stats.derivations, rew.stats.reflexive_added)


@settings(max_examples=25, deadline=None)
@given(
    facts=st.lists(fact, min_size=1, max_size=6),
    sameas_pairs=st.lists(
        st.tuples(st.sampled_from(CONSTS), st.sampled_from(CONSTS)),
        min_size=1,
        max_size=4,
    ),
)
def test_theorem1_with_explicit_equalities(facts, sameas_pairs):
    """Equality-heavy inputs: explicit sameAs facts force merges."""
    sa = [(a, SAME_AS, b) for a, b in sameas_pairs]
    E = np.asarray(list(facts) + sa, dtype=np.int32).reshape(-1, 3)
    P = Program([])
    ax = materialise(E, P, N_RES, mode="AX")
    rew = materialise(E, P, N_RES, mode="REW")
    check_theorem1(rew, ax)


def test_contradiction_direct_both_modes():
    E = np.array([[5, DIFFERENT_FROM, 5]], np.int32)
    for mode in ("AX", "REW"):
        with pytest.raises(Contradiction):
            materialise(E, Program([]), N_RES, mode=mode)


def test_contradiction_via_merge_both_modes():
    """<a,dF,b> plus a sameAs b: only visible after rewriting/replacement."""
    E = np.array([[5, DIFFERENT_FROM, 6], [5, SAME_AS, 6]], np.int32)
    for mode in ("AX", "REW"):
        with pytest.raises(Contradiction):
            materialise(E, Program([]), N_RES, mode=mode)


def test_no_false_contradiction():
    E = np.array([[5, DIFFERENT_FROM, 6], [7, SAME_AS, 6]], np.int32)
    for mode in ("AX", "REW"):
        materialise(E, Program([]), N_RES, mode=mode)  # must not raise
