"""Multi-device SPMD materialisation (subprocess with 4 fake CPU devices).

The main pytest process must keep the default single device (smoke tests and
benches depend on it), so the SPMD run happens in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.data.datasets import pex, pex_rule_rewrite, single_clique
    from repro.core.materialise import materialise
    from repro.core.engine_jax import JaxEngine
    from repro.core.triples import pack
    from repro.launch.mesh import make_mesh

    assert len(jax.devices()) == 4, jax.devices()
    mesh = make_mesh((4,), ("data",))
    for name, ds in [("pex", pex), ("pex_rr", pex_rule_rewrite),
                     ("clique6", lambda: single_clique(6))]:
        facts, prog, dic = ds()
        ref = materialise(facts, prog, dic.n_resources, mode="REW")
        eng = JaxEngine(dic.n_resources, capacity=128, bind_cap=128,
                        out_cap=128, rewrite_cap=128, mesh=mesh)
        spo, rep, stats = eng.materialise(facts, prog)
        assert set(pack(ref.triples()).tolist()) == set(pack(spo).tolist()), name
        assert (rep == ref.rep).all(), name
        assert stats.derivations == ref.stats.derivations, name
        assert stats.rule_applications == ref.stats.rule_applications, name
    print("SPMD-OK")
    """
)


@pytest.mark.slow
def test_spmd_materialisation_4_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SPMD-OK" in out.stdout


_ROUTED_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.data.datasets import pex, pex_rule_rewrite, single_clique
    from repro.data.generator import generate, PROFILES
    from repro.core.materialise import materialise
    from repro.core.engine_jax import JaxEngine
    from repro.core.triples import pack
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    for name, ds in [("pex", pex), ("pex_rr", pex_rule_rewrite),
                     ("clique6", lambda: single_clique(6)),
                     ("uobm", lambda: generate(**PROFILES["uobm_like"]))]:
        facts, prog, dic = ds()
        ref = materialise(facts, prog, dic.n_resources, mode="REW")
        gather = JaxEngine(dic.n_resources, capacity=1 << 13, bind_cap=1 << 13,
                           out_cap=1 << 13, rewrite_cap=1 << 13, mesh=mesh)
        routed = JaxEngine(dic.n_resources, capacity=1 << 13, bind_cap=1 << 13,
                           out_cap=1 << 13, rewrite_cap=1 << 13, mesh=mesh,
                           route_cap=1 << 11)
        spo_g, rep_g, st_g = gather.materialise(facts, prog)
        spo_r, rep_r, st_r = routed.materialise(facts, prog)
        # semantic equality with the numpy reference
        assert set(pack(ref.triples()).tolist()) == set(pack(spo_r).tolist()), name
        assert (rep_r == ref.rep).all(), name
        assert st_r.derivations == ref.stats.derivations, name
        # exact parity between the two exchange schemes
        assert set(pack(spo_g).tolist()) == set(pack(spo_r).tolist()), name
        assert st_r.rule_applications == st_g.rule_applications, name
        assert st_r.rounds == st_g.rounds, name
    print("ROUTED-OK")
    """
)


@pytest.mark.slow
def test_owner_routed_exchange_matches_gather():
    """§Perf cell 1: the all_to_all owner-routing scheme is semantics- and
    stats-identical to the baseline all-gather scheme."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _ROUTED_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ROUTED-OK" in out.stdout
