"""repro.analysis: walker, passes, planted fixtures, dispatch auditor, CLI.

Positive direction: the registered engine/maintenance inventory lints clean
at the probe geometry on every dataset family, and a driven update stream's
runtime dispatches reconcile against the static per-phase profile.

Negative direction (the half a linter test suite usually forgets): each
planted-violation fixture must keep tripping exactly its pass, with a
location precise enough to act on — a pass that stops seeing its fixture
has gone blind, whatever the inventory audit says.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (
    ALL_PASSES,
    DtypeSafety,
    NoArenaScatter,
    NoArenaSort,
    NoHostCallback,
    audit_engine,
    audited_fn_labels,
    build_probe,
    count_sorts_at_least,
    dispatch_crosscheck,
    jaxpr_walk,
)
from repro.analysis.fixtures import (
    ARENA,
    EXPECTED_PASS,
    FIXTURES,
    trace_fixture,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_passes(label, jx, arena_rows):
    vs = []
    for p in ALL_PASSES:
        vs += p.run(label, jx, arena_rows)
    return vs


# ---------------------------------------------------------------------------
# jaxpr_walk: the generic traversal the passes (and the budget tests) share
# ---------------------------------------------------------------------------

def test_jaxpr_walk_reaches_cond_branches_with_path():
    """The planted sort inside a cond branch is reachable, and its path
    names the nesting trail (the historical helper only looked at
    top-level param values, so tuple-of-branches sub-jaxprs need explicit
    coverage)."""
    _label, jx, _rows = trace_fixture("nested_cond_sort")
    sort_paths = [
        path for eqn, path in jaxpr_walk(jx) if eqn.primitive.name == "sort"
    ]
    assert sort_paths, "walker never reached the branch body"
    assert any("cond[branches" in "/".join(p) for p in sort_paths), sort_paths


def test_count_sorts_at_least_thresholds():
    """Arena-length sorts count; cap-width sorts do not (the discrimination
    the probe geometry exists to make unambiguous)."""
    _l, jx, rows = trace_fixture("arena_sort")
    assert count_sorts_at_least(jx, rows) == 1
    assert count_sorts_at_least(jx, rows + 1) == 0  # strictly longer: none


# ---------------------------------------------------------------------------
# planted fixtures: every pass must catch its bug class, with a location
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", FIXTURES)
def test_fixture_trips_expected_pass(name):
    label, jx, rows = trace_fixture(name)
    vs = _run_passes(label, jx, rows)
    hits = [v for v in vs if v.pass_name == EXPECTED_PASS[name]]
    assert hits, (name, [str(v) for v in vs])
    v = hits[0]
    # the report must be actionable: pass, fn, primitive, and a path
    assert v.fn == f"fixture:{name}"
    assert v.primitive
    assert v.path
    assert str(v).startswith(f"[{EXPECTED_PASS[name]}] fixture:{name}:")
    d = v.as_dict()
    assert set(d) >= {"pass_name", "fn", "primitive", "path", "detail"}


def test_nested_fixture_reports_nested_path():
    """The cond-branch plant's location names the branch, not ``<top>``."""
    label, jx, rows = trace_fixture("nested_cond_sort")
    vs = [v for v in NoArenaSort().run(label, jx, rows)]
    assert vs and vs[0].path != "<top>", [str(v) for v in vs]
    assert "cond[branches" in vs[0].path


def test_fixtures_do_not_cross_fire():
    """Each fixture trips only its own pass family — a scatter plant must
    not look like a sort violation and vice versa (pass independence)."""
    others = {
        "arena_sort": NoArenaScatter(),
        "arena_scatter": NoArenaSort(),
        "int32_key": NoHostCallback(),
        "host_callback": DtypeSafety(),
    }
    for name, p in others.items():
        label, jx, rows = trace_fixture(name)
        assert p.run(label, jx, rows) == [], (name, p.name)


def test_dtype_safety_allows_widening_and_untainted_casts():
    """Only narrowing casts of packed-key-tainted values violate: widening
    a key further, or narrowing a value that never saw a pack, is fine."""
    import jax
    import jax.numpy as jnp
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental import enable_x64

    def benign(s, x):
        key = (s.astype(jnp.int64) << jnp.int64(21)) | s.astype(jnp.int64)
        return key + jnp.int64(1), x.astype(jnp.int32)  # untainted narrow

    with enable_x64():
        jx = jax.make_jaxpr(benign)(
            jnp.zeros(8, jnp.int32), jnp.zeros(8, jnp.int64)
        )
    assert DtypeSafety().run("benign", jx, ARENA) == []


# ---------------------------------------------------------------------------
# positive direction: the registered inventory lints clean on every family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataset", ["chain", "clique", "dbpedia_like"])
def test_inventory_lints_clean(dataset):
    engine, state, program = build_probe(dataset)
    vs = audit_engine(engine, state)
    assert vs == [], [str(v) for v in vs]
    labels = audited_fn_labels(engine, state)
    # the inventory covers the whole maintenance surface, not one fn
    fams = {lbl.split(":")[0] for lbl in labels}
    assert fams >= {
        "process", "squeeze", "rebuild_index", "seed_tombs",
        "od", "finalize_tombs", "extract_od", "member", "occupancy",
        "fforward", "fwave",
    }, fams
    if program.rules:  # pure-sameAs profiles have no rule plans to trace
        assert {"plan", "rplan"} <= fams, fams


def test_driven_stream_dispatches_reconcile():
    """Real add+delete events through the engine leave a dispatch counter
    the static phase profile fully admits (the runtime half of the
    DispatchAuditor), and the phase tags reset after each operation."""
    engine, state, program = build_probe("clique")
    explicit = state.explicit
    engine.delete_facts(state, explicit[:2])
    engine.add_facts(state, explicit[:2])
    assert engine.dispatches.phase is None  # generators reset their tag
    assert engine.dispatches.total > 0
    tagged = [ph for (ph, _f) in engine.dispatches.by_phase if ph is not None]
    assert tagged, "no phase-tagged dispatches recorded"
    assert dispatch_crosscheck(engine.dispatches, program) == []


# ---------------------------------------------------------------------------
# dispatch cross-check semantics (pure, no tracing)
# ---------------------------------------------------------------------------

def test_dispatch_crosscheck_flags_unknowns():
    from repro.core.stats import DispatchCounter

    c = DispatchCounter()
    c.phase = "add:forward"
    c.record("process")          # admitted
    c.phase = "add:mystery"
    c.record("process")          # unknown phase
    c.phase = "delete:wave"
    c.record("rogue")            # unregistered family in a known phase
    c.phase = "retry"
    c.record("rebuild_index")    # capacity-retry recovery: admitted
    c.phase = None
    c.record("anything")         # untagged: never checked
    probs = dispatch_crosscheck(c)
    assert len(probs) == 2, probs
    assert any("unknown phase 'add:mystery'" in p for p in probs)
    assert any(
        "delete:wave" in p and "'rogue'" in p and "static profile allows" in p
        for p in probs
    )


def test_dispatch_counter_snapshot_and_reset():
    from repro.core.stats import DispatchCounter

    c = DispatchCounter()
    c.record("a")
    c.record("a")
    c.record_compile("a")
    snap = c.snapshot()
    assert snap["total"] == 2 and snap["by_family"] == {"a": 2}
    c.reset()
    assert c.total == 0 and not c.by_family and not c.compiles
    assert c.phase is None


# ---------------------------------------------------------------------------
# serving surface: TripleStore exposes its dispatch ledger + audit
# ---------------------------------------------------------------------------

def test_triple_store_dispatch_counts_and_audit():
    from repro.data.generator import generate, sample_update_stream
    from repro.serve.triple_store import TripleStore

    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=25,
        hierarchy_depth=1, seed=2,
    )
    store = TripleStore(facts, prog, dic)
    for op, delta in sample_update_stream(facts, dic, n_events=2, batch=5,
                                          seed=2):
        store.submit_update(op, delta)
    store.drain()
    assert store.audit() == []
    d = store.dispatch_counts
    assert d["total"] > 0
    assert d["by_family"]
    assert d["by_phase"] and all("/" in k for k in d["by_phase"])
    assert sum(d["by_phase"].values()) <= d["total"]
    assert d["compiles_by_family"]
    # compiles are cache fills, strictly rarer than dispatches per family
    for fam, n in d["compiles_by_family"].items():
        assert d["by_family"].get(fam, 0) >= 0 and n >= 1


# ---------------------------------------------------------------------------
# CLI: the tier-1 entry point the CI gate shells
# ---------------------------------------------------------------------------

def _cli(*args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout,
    )


def test_cli_check_passes_on_inventory(tmp_path):
    out_json = tmp_path / "report.json"
    r = _cli("--check", "--json", str(out_json))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 violation(s), 0 dispatch problem(s)" in r.stdout
    report = json.loads(out_json.read_text())
    assert report["violations"] == []
    assert report["dispatch"]["problems"] == []
    assert report["fns"] and report["passes"]
    assert report["dispatch"]["total"] > 0
    # static profile covers every runtime-observed phase/family pair
    for key in report["dispatch"]["runtime_by_phase"]:
        ph, fam = key.rsplit("/", 1)
        assert fam in report["dispatch"]["static_profile"][ph], key


@pytest.mark.parametrize("name", FIXTURES)
def test_cli_fixture_exits_nonzero(name):
    r = _cli("--fixture", name, "--json", "-")
    # rc 1 == expected pass fired (rc 2 would mean the audit went blind)
    assert r.returncode == 1, (name, r.returncode, r.stdout + r.stderr)
    assert EXPECTED_PASS[name] in r.stdout
    assert "fired as planted" in r.stdout
