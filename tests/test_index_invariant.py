"""Persistent sorted arena index: invariant fuzz + the sort-op budget.

The tentpole contract (ISSUE 4): ``EngineState.sorted_keys`` always equals
``sort(pack3(live rows))`` per shard — maintained by merge-on-insert and
stable-partition removal, NEVER by re-sorting the arena — and the arena is
argsorted at most once per *mutation epoch* (capacity re-layout), asserted
two ways below: a jaxpr trace proving the compiled round fns contain no
arena-length sort primitive, and the ``stats.index_rebuilds`` counter.

Traces cover chain/clique/dbpedia-style workloads after every add/delete
phase, capacity-retry restarts, and epoch barriers, on 1 device in-process
plus 1/2/4 virtual devices in a subprocess (``slow``).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine_jax import JaxEngine, index_invariant_report
from repro.core.incremental_spmd import spmd_add_phases, spmd_delete_phases
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op, pack
from repro.data.datasets import clique_with_spokes, pex, single_clique
from repro.data.generator import generate, sample_update_stream


def _engine(dic, cap=1 << 10, **kw):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap, **kw,
    )


def _assert_clean(eng, state, where=""):
    probs = index_invariant_report(state, eng.n_shards)
    assert probs == [], (where, probs)


# ---------------------------------------------------------------------------
# invariant after every phase / operation (chain, clique, dbpedia-style)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "ds",
    [
        lambda: single_clique(8),                      # chain of sameAs
        lambda: clique_with_spokes(6, 4),              # clique + payload
        lambda: generate(n_groups=2, group_size=3, n_spokes_per=2,
                         n_plain=40, hierarchy_depth=2, chain_rules=True,
                         seed=5),                      # dbpedia-style rules
    ],
    ids=["chain", "clique", "dbpedia_like"],
)
def test_index_invariant_after_every_phase(ds):
    from repro.core.engine_jax import enable_x64

    facts, prog, dic = ds()
    eng = _engine(dic)
    state = eng.materialise_state(facts, prog)
    _assert_clean(eng, state, "base")
    events = sample_update_stream(facts, dic, n_events=4, batch=6, seed=1)
    explicit = facts
    for i, (op, delta) in enumerate(events):
        explicit = apply_op(explicit, op, delta)
        gen = (spmd_add_phases if op == "add" else spmd_delete_phases)(
            eng, state, delta, 10_000
        )
        eng._set_update_buffers(True)
        with enable_x64():
            for phase in gen:
                _assert_clean(eng, state, f"event {i} phase {phase}")
        eng._barrier(state)
        _assert_clean(eng, state, f"event {i} barrier")
        ref = materialise_rew(explicit, prog, dic.n_resources)
        got = set(pack(eng.state_triples(state)).tolist())
        assert got == set(pack(ref.triples()).tolist()), (i, op)
    assert state.stats.index_rebuilds == 0  # no growth -> no full argsort


def test_index_invariant_across_capacity_retry_restart():
    """A mid-update CapacityError rolls back, re-lays-out the arena, and
    rebuilds the index exactly once (the per-epoch argsort budget)."""
    facts, prog, dic = clique_with_spokes(7, 4)
    base = _engine(dic)
    used = int(np.asarray(base.materialise_state(facts, prog).n_used).sum())
    eng = JaxEngine(dic.n_resources, capacity=used + 2, bind_cap=1 << 10,
                    out_cap=1 << 10, rewrite_cap=1 << 10)
    state = eng.materialise_state(facts, prog)
    _assert_clean(eng, state, "snug base")
    rebuilds0 = state.stats.index_rebuilds
    eng.delete_facts(state, facts[2:4])  # forces arena growth + restart
    assert eng.capacity > used + 2
    _assert_clean(eng, state, "after growth")
    assert not state.index_dirty
    assert state.stats.index_rebuilds - rebuilds0 == 1
    remaining = np.concatenate([facts[:2], facts[4:]], axis=0)
    ref = materialise_rew(remaining, prog, dic.n_resources)
    got = set(pack(eng.state_triples(state)).tolist())
    assert got == set(pack(ref.triples()).tolist())


def test_index_invariant_at_serving_epoch_barriers():
    """The serving scheduler's tick loop keeps the invariant at every tick,
    and snapshots read through the index match the mask-scan extraction."""
    from repro.serve.triple_store import TripleStore

    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=25,
        hierarchy_depth=1, seed=2,
    )
    store = TripleStore(facts, prog, dic)
    _assert_clean(store.engine, store.state, "epoch 0")
    events = sample_update_stream(facts, dic, n_events=3, batch=5, seed=2)
    for op, delta in events:
        store.submit_update(op, delta)
    ticks = 0
    while store.pending():
        store.step()
        ticks += 1
        assert ticks < 10_000
        if store.inflight is None:  # epoch barrier
            _assert_clean(store.engine, store.state, f"tick {ticks}")
    snap = store.snapshot
    live = (np.asarray(store.state.epoch) >= 0) & ~np.asarray(store.state.marked)
    want = np.asarray(store.state.spo)[live]
    assert set(pack(snap.triples).tolist()) == set(pack(want).tolist())
    # index extraction publishes packed-key-sorted triples per shard
    assert (np.diff(pack(snap.triples)) > 0).all()


# ---------------------------------------------------------------------------
# the sort-op budget: no arena-length sort primitive inside the round fns
# (the recursive jaxpr walker lives in repro.analysis — shared with the
# lint CLI, which audits the same inventory through the engine registry)
# ---------------------------------------------------------------------------

from repro.analysis import count_sorts_at_least as _sorts_at_least


def test_no_arena_sort_in_round_fns():
    """Trace test for the acceptance budget: neither the process step nor
    any plan evaluation contains a sort over arena-length operands — only
    the (cap-sized) candidate stream / binding sorts remain, and the single
    allowed arena argsort lives in the explicit rebuild fn."""
    import jax

    from repro.core.engine_jax import enable_x64
    from repro.data.datasets import pex

    facts, prog, dic = pex()
    # arena strictly larger than every other buffer so arena-length sorts
    # are unambiguous in the traces
    eng = JaxEngine(dic.n_resources, capacity=4096, bind_cap=256, out_cap=256,
                    rewrite_cap=256)
    state = eng.materialise_state(facts, prog)
    arena_rows = int(state.spo.shape[0])
    assert arena_rows > 4 * max(eng.bind_cap, eng.out_cap, eng.rewrite_cap)

    with enable_x64():
        import jax.numpy as jnp

        from repro.core.engine_jax import I32, eval_plan, process_candidates
        from functools import partial

        cands = jnp.zeros((eng.out_cap, 3), I32)
        cv = jnp.zeros((eng.out_cap,), bool)
        proc = partial(
            process_candidates, rewrite_cap=eng.rewrite_cap, axis=None,
            n_shards=1, route_cap=None, pair_cap=eng.pair_cap,
        )
        jx = jax.make_jaxpr(proc)(
            state.spo, state.epoch, state.marked, state.n_used, state.rep,
            state.sort_perm, state.sorted_keys, cands, cv, jnp.asarray(1, I32),
        )
        assert _sorts_at_least(jx.jaxpr, arena_rows) == 0

        from repro.core.engine_jax import build_plans

        for rule in prog.rules:
            for full in (False, True):
                for plan in build_plans(rule, full=full):
                    consts = jnp.zeros((len(rule.body), 3), I32)
                    hc = jnp.zeros((3,), I32)
                    slots = tuple(
                        t if isinstance(t, int) and t < 0 else None
                        for t in rule.head
                    )
                    fn = partial(
                        eval_plan, plan=tuple(plan), head_var_slots=slots,
                        bind_cap=eng.bind_cap, out_cap=eng.out_cap, axis=None,
                    )
                    jx = jax.make_jaxpr(fn)(
                        state.spo, state.epoch, state.marked, state.tomb,
                        state.sorted_keys, state.sort_perm,
                        jnp.asarray(1, I32), consts, hc,
                    )
                    assert _sorts_at_least(jx.jaxpr, arena_rows) == 0, rule

        # the targeted rederive joins obey the same budget: seed table and
        # binding sorts are cap-sized, the arena is only range-probed
        from repro.core.engine_jax import build_rederive_plan, eval_plan_rederive

        for rule in prog.rules:
            plan, seed_vars = build_rederive_plan(rule)
            if not seed_vars:
                continue  # variable-free head: whole-rule fallback instead
            consts = jnp.zeros((len(rule.body), 3), I32)
            hc = jnp.zeros((3,), I32)
            slots = tuple(
                t if isinstance(t, int) and t < 0 else None
                for t in rule.head
            )
            seeds = jnp.zeros((64, len(seed_vars)), I32)
            sv = jnp.zeros((64,), bool)
            fn = partial(
                eval_plan_rederive, plan=tuple(plan), head_var_slots=slots,
                seed_vars=seed_vars, bind_cap=eng.bind_cap,
                out_cap=eng.out_cap, axis=None,
            )
            jx = jax.make_jaxpr(fn)(
                state.spo, state.epoch, state.marked, state.tomb,
                state.sorted_keys, state.sort_perm, consts, hc, seeds, sv,
            )
            assert _sorts_at_least(jx.jaxpr, arena_rows) == 0, rule


def test_rebuild_counter_budget_over_stream():
    """<= one full argsort per mutation epoch across a whole update stream:
    rebuilds only ever accompany capacity growth."""
    facts, prog, dic = generate(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=30,
        hierarchy_depth=1, seed=4,
    )
    eng = _engine(dic, cap=1 << 11)
    state = eng.materialise_state(facts, prog)
    events = sample_update_stream(facts, dic, n_events=5, batch=8, seed=4)
    epochs = 0
    for op, delta in events:
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        epochs += 1
        assert state.stats.index_rebuilds <= epochs
    assert state.stats.index_rebuilds == 0  # ample caps: zero full sorts


# ---------------------------------------------------------------------------
# hypothesis fuzz (nightly) + device-count invariance (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_index_invariant_hypothesis_fuzz():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    facts0, prog0, dic0 = clique_with_spokes(5, 3)

    @settings(max_examples=25, deadline=None)
    @given(
        st.lists(
            st.tuples(
                st.sampled_from(["add", "delete"]),
                st.lists(st.integers(0, facts0.shape[0] - 1), min_size=1,
                         max_size=4),
            ),
            min_size=1, max_size=4,
        )
    )
    def run(script):
        eng = _engine(dic0, cap=512)
        state = eng.materialise_state(facts0, prog0)
        explicit = facts0
        for op, idxs in script:
            delta = facts0[np.asarray(sorted(set(idxs)))]
            explicit = apply_op(explicit, op, delta)
            (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
            _assert_clean(eng, state, (op, idxs))
            ref = materialise_rew(explicit, prog0, dic0.n_resources)
            got = set(pack(eng.state_triples(state)).tolist())
            assert got == set(pack(ref.triples()).tolist())

    run()


_MESH_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.core.engine_jax import JaxEngine, index_invariant_report
    from repro.core.materialise import materialise_rew
    from repro.core.triples import apply_op, pack
    from repro.data.generator import generate, sample_update_stream
    from repro.launch.mesh import make_engine_mesh

    assert len(jax.devices()) == 4, jax.devices()
    facts, prog, dic = generate(n_groups=2, group_size=3, n_spokes_per=1,
                                n_plain=15, hierarchy_depth=1, seed=3)
    events = sample_update_stream(facts, dic, n_events=3, batch=6, seed=3)
    for n_dev, route in ((1, None), (2, None), (4, None), (4, 256)):
        eng = JaxEngine(dic.n_resources, capacity=1 << 10, bind_cap=1 << 10,
                        out_cap=1 << 10, rewrite_cap=1 << 10,
                        mesh=make_engine_mesh(n_dev), route_cap=route,
                        seed_chunk=128)
        state = eng.materialise_state(facts, prog)
        assert index_invariant_report(state, eng.n_shards) == [], ("base", n_dev)
        explicit = facts
        for op, delta in events:
            explicit = apply_op(explicit, op, delta)
            (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
            probs = index_invariant_report(state, eng.n_shards)
            assert probs == [], (n_dev, route, op, probs)
            ref = materialise_rew(explicit, prog, dic.n_resources)
            got = set(pack(eng.state_triples(state)).tolist())
            assert got == set(pack(ref.triples()).tolist()), (n_dev, op)
    print("INDEX-INVARIANT-OK")
    """
)


@pytest.mark.slow
def test_index_invariant_device_count_invariant():
    """The per-shard invariant holds on 1/2/4 virtual devices, gather and
    owner-routed exchange alike."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "INDEX-INVARIANT-OK" in out.stdout
