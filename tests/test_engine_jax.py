"""JAX fixed-capacity engine vs numpy reference engine equivalence."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.engine_jax import JaxEngine
from repro.core.materialise import Contradiction, materialise
from repro.core.rules import Program, Rule
from repro.core.terms import DIFFERENT_FROM, SAME_AS
from repro.core.triples import pack
from repro.data.datasets import pex, pex_rule_rewrite, single_clique


def _sets_equal(a: np.ndarray, b: np.ndarray) -> bool:
    return set(pack(a).tolist()) == set(pack(b).tolist())


@pytest.mark.parametrize(
    "ds", [pex, pex_rule_rewrite, lambda: single_clique(4)], ids=["pex", "pex_rr", "clique4"]
)
def test_jax_engine_matches_reference(ds):
    facts, prog, dic = ds()
    ref = materialise(facts, prog, dic.n_resources, mode="REW")
    eng = JaxEngine(dic.n_resources, capacity=256, bind_cap=256, out_cap=256, rewrite_cap=256)
    spo, rep, stats = eng.materialise(facts, prog)
    assert _sets_equal(ref.triples(), spo)
    assert (rep == ref.rep).all()
    assert stats.derivations == ref.stats.derivations
    assert stats.rule_applications == ref.stats.rule_applications
    assert stats.merged_resources == ref.stats.merged_resources
    assert stats.reflexive_added == ref.stats.reflexive_added


def test_capacity_growth_retry():
    """Tiny initial capacities must transparently grow, not fail."""
    facts, prog, dic = single_clique(6)
    eng = JaxEngine(dic.n_resources, capacity=4, bind_cap=4, out_cap=4, rewrite_cap=4)
    spo, rep, stats = eng.materialise(facts, prog)
    ref = materialise(facts, prog, dic.n_resources, mode="REW")
    assert _sets_equal(ref.triples(), spo)
    assert eng.capacity > 4  # growth happened


def test_contradiction_raised():
    eng = JaxEngine(10, capacity=64, bind_cap=64, out_cap=64, rewrite_cap=64)
    E = np.array([[5, DIFFERENT_FROM, 6], [5, SAME_AS, 6]], np.int32)
    with pytest.raises(Contradiction):
        eng.materialise(E, Program([]))


N_RES = 9
CONSTS = list(range(3, N_RES))
PREDS = CONSTS + [SAME_AS]
VARS = [-1, -2]

fact = st.tuples(st.sampled_from(CONSTS), st.sampled_from(PREDS), st.sampled_from(CONSTS))


@st.composite
def rule(draw):
    body = tuple(
        draw(
            st.lists(
                st.tuples(
                    st.sampled_from(CONSTS + VARS),
                    st.sampled_from(PREDS),
                    st.sampled_from(CONSTS + VARS),
                ),
                min_size=1,
                max_size=2,
            )
        )
    )
    body_vars = [t for a in body for t in a if t < 0]
    head_so = st.sampled_from(CONSTS + body_vars) if body_vars else st.sampled_from(CONSTS)
    head = (draw(head_so), draw(st.sampled_from(PREDS)), draw(head_so))
    return Rule(head, body)


@settings(max_examples=15, deadline=None)
@given(
    facts=st.lists(fact, min_size=1, max_size=6),
    rules=st.lists(rule(), min_size=0, max_size=2),
)
def test_jax_engine_random_equivalence(facts, rules):
    E = np.asarray(facts, np.int32).reshape(-1, 3)
    P = Program(rules)
    ref = materialise(E, P, N_RES, mode="REW")
    eng = JaxEngine(N_RES, capacity=512, bind_cap=512, out_cap=512, rewrite_cap=512)
    spo, rep, stats = eng.materialise(E, P)
    assert _sets_equal(ref.triples(), spo)
    assert (rep == ref.rep).all()
    assert stats.derivations == ref.stats.derivations
