"""The fused on-device fixpoint vs the host round loop and the oracle.

Three fronts:

* **Differential** — fused (default), host-loop (``fuse_rounds=False``) and
  the from-scratch REW materialisation agree after every event of an update
  stream, over the four profile shapes of tests/test_incremental_spmd.py
  (the 1/2/4-device matrix lives there, in the mesh subprocess script's
  ``*_nofuse`` cells).
* **Trace shape** — the registered ``fforward`` trace contains exactly ONE
  top-level while_loop (the fixpoint) and zero arena-length sorts; the
  dispatch count of a fused maintenance stream stays under the host loop's.
* **Attribution bugfixes riding along** — capacity-retry dispatches land in
  a distinct ``"retry"`` phase, an empty admitted batch presizes to the
  minimum delta width without booking ``wide_growth_restarts``, and the
  sticky wide-buffer fallback's narrow probe is keyed off epoch barriers
  (fallback exits after load drops even though the fused loop advances
  rounds on device).
"""

import numpy as np
import pytest

from repro.analysis import build_probe, count_sorts_at_least
from repro.core.engine_jax import CapacityError, JaxEngine
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op as _apply, pack
from repro.data.generator import generate, sample_update_stream


def _packset(spo):
    return set(pack(np.asarray(spo, np.int32).reshape(-1, 3)).tolist())


def _engine(dic, cap=1 << 11, **kw):
    return JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap, **kw,
    )


# same profile shapes as tests/test_incremental_spmd.py's _MODE_COMBOS
_COMBOS = [
    (dict(n_groups=1, group_size=5, n_spokes_per=2, n_plain=8,
          hierarchy_depth=0), 3, "clique_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=25,
          hierarchy_depth=3), 5, "chain_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=30,
          hierarchy_depth=1, chain_rules=True), 7, "dbpedia_ish"),
    (dict(n_groups=2, group_size=3, n_spokes_per=1, n_plain=15,
          hierarchy_depth=1, hometown_groups=1, hometown_size=5), 9,
     "uobm_ish"),
]


# ---------------------------------------------------------------------------
# differential: fused == host loop == from-scratch, per event
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "gen_kw, seed, _id", _COMBOS, ids=[c[-1] for c in _COMBOS]
)
def test_fused_vs_host_vs_scratch(gen_kw, seed, _id):
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(facts, dic, n_events=4, batch=8, seed=seed)
    engines = {
        "fused": _engine(dic, fuse_rounds=True),
        "host": _engine(dic, fuse_rounds=False),
    }
    states = {m: e.materialise_state(facts, prog) for m, e in engines.items()}
    explicit = facts
    for i, (op, delta) in enumerate(events):
        explicit = _apply(explicit, op, delta)
        ref = materialise_rew(explicit, prog, dic.n_resources)
        want = _packset(ref.triples())
        for m, e in engines.items():
            (e.add_facts if op == "add" else e.delete_facts)(states[m], delta)
            assert _packset(e.state_triples(states[m])) == want, (i, m, op)
            rep = e.state_rep(states[m])
            assert (rep[: ref.rep.shape[0]] == ref.rep).all(), (i, m, op)
    # the fused engine genuinely orchestrated on device: fewer dispatches
    # for the same work (the point of the subsystem)
    assert (
        engines["fused"].dispatches.total < engines["host"].dispatches.total
    ), (engines["fused"].dispatches.total, engines["host"].dispatches.total)


def test_fused_with_dedup_kernel_matches_scratch():
    """use_kernel=True swaps the in-loop argsorts for the counting-rank
    kernel; the fused fixpoint must be bit-equal to the oracle with it."""
    gen_kw, seed, _ = _COMBOS[0]
    facts, prog, dic = generate(**gen_kw, seed=seed)
    events = sample_update_stream(facts, dic, n_events=3, batch=6, seed=seed)
    eng = _engine(dic, cap=256, fuse_rounds=True, use_kernel=True)
    state = eng.materialise_state(facts, prog)
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
        ref = materialise_rew(explicit, prog, dic.n_resources)
        assert _packset(eng.state_triples(state)) == _packset(ref.triples())


# ---------------------------------------------------------------------------
# trace shape: one while_loop, no arena sorts
# ---------------------------------------------------------------------------

def _traced(engine, state, name):
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental import enable_x64

    from repro.core import incremental_spmd  # noqa: F401 (registers fns)
    from repro.core.engine_jax import AUDIT_REGISTRY

    with enable_x64():
        return dict(AUDIT_REGISTRY[name].builder(engine, state))


@pytest.mark.parametrize("name", ["fforward", "fwave"])
def test_fused_trace_is_one_while_loop(name):
    """The fused fn IS the fixpoint: exactly one while_loop at the top
    level (merge_pairs_jax nests its own pointer-jumping loops INSIDE the
    body — only the top level counts) and zero arena-length sorts anywhere
    (the index is maintained incrementally; rebuild stays outside)."""
    engine, state, _prog = build_probe("pex")
    jx = _traced(engine, state, name)[name]
    top_whiles = [e for e in jx.jaxpr.eqns if e.primitive.name == "while"]
    assert len(top_whiles) == 1, [e.primitive.name for e in jx.jaxpr.eqns]
    arena_rows = int(state.spo.shape[0])
    assert count_sorts_at_least(jx, arena_rows) == 0


# ---------------------------------------------------------------------------
# dispatch attribution across capacity retries
# ---------------------------------------------------------------------------

def test_retry_dispatches_get_their_own_phase():
    """_recover_capacity re-tags the counter before touching the state, so
    recovery dispatches never masquerade as work of the phase that
    overflowed — and the crosscheck admits the "retry" phase."""
    from repro.analysis import dispatch_crosscheck

    gen_kw, seed, _ = _COMBOS[0]
    facts, prog, dic = generate(**gen_kw, seed=seed)
    eng = _engine(dic, cap=256)
    state = eng.materialise_state(facts, prog)

    snap = eng._snapshot(state)
    eng.dispatches.phase = "delete:wave"  # stale tag at overflow time
    eng._recover_capacity(state, snap, CapacityError("bind"))
    assert eng.dispatches.phase == "retry"
    assert state.stats.capacity_retries == 1
    eng.dispatches.phase = None

    assert dispatch_crosscheck(eng.dispatches, prog) == []


def test_forced_overflow_stream_reconciles():
    """An update stream that genuinely trips the capacity retry leaves a
    counter the static profile fully admits (retry phase included)."""
    from repro.analysis import dispatch_crosscheck

    facts, prog, dic = generate(
        n_groups=2, group_size=4, n_spokes_per=2, n_plain=60,
        hierarchy_depth=2, seed=11,
    )
    # wide caps large enough to converge, delta caps squeezed so the
    # maintenance stream must discover its width by overflow at least once
    eng = JaxEngine(
        dic.n_resources, capacity=1 << 11, bind_cap=1 << 11, out_cap=1 << 11,
        rewrite_cap=1 << 11, delta_out_cap=2,
    )
    state = eng.materialise_state(facts, prog)
    events = sample_update_stream(facts, dic, n_events=3, batch=16, seed=11)
    explicit = facts
    for op, delta in events:
        explicit = _apply(explicit, op, delta)
        (eng.add_facts if op == "add" else eng.delete_facts)(state, delta)
    ref = materialise_rew(explicit, prog, dic.n_resources)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())
    assert dispatch_crosscheck(eng.dispatches, prog) == []


# ---------------------------------------------------------------------------
# _presize_delta on an empty admitted batch
# ---------------------------------------------------------------------------

def test_empty_batch_presize_books_no_wide_growth():
    """A no-op epoch presizes from cardinality 0: the clamp keeps the delta
    width at its minimum instead of a 0-row presize the next phase would
    repair with a width-discovery restart booked on an idle epoch."""
    gen_kw, seed, _ = _COMBOS[1]
    facts, prog, dic = generate(**gen_kw, seed=seed)
    eng = _engine(dic, cap=512)
    state = eng.materialise_state(facts, prog)

    eng._presize_delta(0)
    assert eng.delta_out >= 1  # minimum pow2 width, not degenerate 0

    before = (
        state.stats.wide_growth_restarts, state.stats.capacity_retries,
        eng.delta_out, eng.delta_bind, eng.delta_rewrite,
    )
    eng.add_facts(state, np.zeros((0, 3), np.int32))
    eng.delete_facts(state, np.zeros((0, 3), np.int32))
    after = (
        state.stats.wide_growth_restarts, state.stats.capacity_retries,
        eng.delta_out, eng.delta_bind, eng.delta_rewrite,
    )
    assert before == after, (before, after)


# ---------------------------------------------------------------------------
# sticky fallback's narrow probe is epoch-keyed
# ---------------------------------------------------------------------------

def test_fallback_narrow_probe_keyed_off_epochs():
    """Once in the wide-buffer fallback, 4 epoch barriers after entry the
    next operation retries the narrow buffers — counted in operations, not
    rounds (the fused loop advances rounds on device, so any round-based
    schedule would stall at one tick per fixpoint)."""
    gen_kw, seed, _ = _COMBOS[0]
    facts, prog, dic = generate(**gen_kw, seed=seed)
    eng = _engine(dic, cap=512)
    state = eng.materialise_state(facts, prog)

    eng._delta_fallback = True  # as left by a delta-width overflow storm
    eng._fallback_since = None
    row = facts[:1]
    epochs_in_fallback = 0
    for _ in range(6):
        if not eng._delta_fallback:
            break
        epochs_in_fallback += 1
        eng.delete_facts(state, row)
        eng.add_facts(state, row)
    # load dropped (tiny updates): the probe fired after 4 epoch barriers
    # and fallback exited — it must not stay sticky forever
    assert not eng._delta_fallback
    assert epochs_in_fallback >= 2  # stayed wide through the window...
    assert eng._fallback_since is None  # ...and the clock reset on exit
    ref = materialise_rew(facts, prog, dic.n_resources)
    assert _packset(eng.state_triples(state)) == _packset(ref.triples())


# ---------------------------------------------------------------------------
# one rho change books rule_rewrites exactly once (fused exit re-run dedupe)
# ---------------------------------------------------------------------------

def test_remerge_booked_once_across_fused_exit():
    """A rho re-merge that rewrites a rule constant books ``rule_rewrites``
    exactly once (and ``rules_requeued`` once per changed rule), identically
    across the fused engine — whose rewrite-due exit round is nullified on
    device and re-run by the host, the historical double-booking hazard —
    the host round loop, and the numpy oracle.  All booking flows through
    the single ``_rewrite_program`` site, so the counters cannot diverge."""
    from repro.core.rules import parse_program
    from repro.core.terms import Dictionary

    dic = Dictionary()
    b, a = dic.intern(":b"), dic.intern(":a")  # b first: merge rep is b
    prog = parse_program(["(?x, :anchored, :a) <- (?x, :q, :a)"], dic)
    q = dic.id_of(":q")
    u = dic.intern(":u")
    for i in range(20):
        dic.intern(f":pad{i}")
    facts = np.asarray([[u, q, b]], np.int32)
    delta = np.asarray([[a, 1, b]], np.int32)  # owl:sameAs merge a -> b

    ref = materialise_rew(
        np.concatenate([facts, delta]), prog, dic.n_resources
    )
    want = _packset(ref.triples())

    booked = {}
    for label, fuse in (("fused", True), ("host", False)):
        eng = _engine(dic, cap=256, fuse_rounds=fuse)
        st = eng.materialise_state(facts, prog)
        before = (st.stats.rule_rewrites, st.stats.rules_requeued)
        eng.add_facts(st, delta)
        booked[label] = (st.stats.rule_rewrites - before[0],
                         st.stats.rules_requeued - before[1])
        assert _packset(eng.state_triples(st)) == want, label
        # ... and the re-merge was evaluated anchored, not whole-rule
        assert st.stats.remerge_targeted >= 1, label
        assert st.stats.full_plan_evals == 0, label
    assert booked["fused"] == booked["host"] == (
        ref.stats.rule_rewrites, ref.stats.rules_requeued
    ) == (1, 1)
