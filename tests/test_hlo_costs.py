"""Loop-aware HLO cost analysis: scanned == unrolled after trip-count
correction; dot flops exact; collectives multiplied by trip counts."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_costs import analyse_hlo, xla_cost_analysis


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_match_xla_on_loop_free():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w2 = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, w1, w2).compile()
    mine = analyse_hlo(compiled.as_text())
    xla = xla_cost_analysis(compiled)
    # dots dominate; allow elementwise accounting slack
    assert abs(mine["flops"] - xla["flops"]) / xla["flops"] < 0.05
    assert mine["transcendentals"] == xla["transcendentals"]


def test_scan_trip_count_correction():
    def body(x, w):
        return jnp.tanh(x @ w), ()

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    a_scan = analyse_hlo(_compiled_text(scanned, x, ws))
    a_unrl = analyse_hlo(_compiled_text(unrolled, x, ws))
    assert a_scan["max_multiplier"] == 8
    np.testing.assert_allclose(a_scan["flops"], a_unrl["flops"], rtol=0.02)


def test_nested_scan_multipliers_compose():
    def inner(x, w):
        return x @ w, ()

    def outer(x, ws):
        def outer_body(x, _):
            y, _ = jax.lax.scan(inner, x, ws)
            return y, ()

        return jax.lax.scan(outer_body, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    a = analyse_hlo(_compiled_text(outer, x, ws))
    expect = 3 * 5 * 2 * 64 * 64 * 64  # 15 dots of 2*64^3
    np.testing.assert_allclose(a["flops"], expect, rtol=0.02)


def test_collectives_in_loops_are_multiplied():
    import subprocess
    import sys
    import textwrap

    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_costs import analyse_hlo
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4,), ("d",))
        sh = NamedSharding(mesh, P("d"))

        def body(c, _):
            # forces an all-reduce inside the scan body
            s = jax.lax.with_sharding_constraint(c * 2.0, sh)
            return s + s.sum() * 0.0 + s, ()

        def f(x):
            y, _ = jax.lax.scan(body, x, None, length=6)
            return y.sum()

        x = jax.ShapeDtypeStruct((64,), jnp.float32)
        with mesh:
            txt = (jax.jit(f, in_shardings=sh).lower(x).compile().as_text())
        a = analyse_hlo(txt)
        # one all-reduce per iteration => counted 6x
        kinds = a["collectives"]
        total = sum(v["count"] for v in kinds.values())
        assert total >= 6, (total, kinds)
        print("OK", total)
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=300, cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-1500:]
    assert "OK" in out.stdout
