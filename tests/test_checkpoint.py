"""Checkpoint layer: atomicity, retention, async writer, corrupted-tmp
recovery, structure mismatch detection."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "b": jnp.arange(16, dtype=jnp.bfloat16),
        "nested": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 3, t, aux={"next_step": 3})
    out, aux, step = restore_checkpoint(str(tmp_path), t)
    assert step == 3 and aux["next_step"] == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, out)
    assert out["b"].dtype == jnp.bfloat16


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    for s in (1, 5, 9):
        mgr.save(s, t)
    assert latest_step(str(tmp_path)) == 9
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2  # keep=2


def test_crashed_tmp_dir_is_ignored(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 2, t)
    # a writer that died mid-flight leaves a tmp dir — must not be visible
    os.makedirs(tmp_path / "step_000000007.tmp-9999")
    assert latest_step(str(tmp_path)) == 2
    out, _, step = restore_checkpoint(str(tmp_path), t)
    assert step == 2


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree())
    wrong = {"other": jnp.zeros(3)}
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_checkpoint(str(tmp_path), wrong)


def test_async_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = tree()
    for s in range(4):
        mgr.save(s, t, aux={"next_step": s})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 3
    out, aux, _ = restore_checkpoint(str(tmp_path), t)
    assert aux["next_step"] == 3
    mgr.close()


def test_async_snapshot_isolation(tmp_path):
    """The async save must snapshot values at call time, not write time."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    v = {"x": jnp.zeros(4)}
    mgr.save(0, v)
    v["x"] = v["x"] + 100.0  # donated/updated after the call
    mgr.wait()
    out, _, _ = restore_checkpoint(str(tmp_path), v)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.zeros(4))
    mgr.close()
