"""Unit tests for the core substrate: dictionary, packing, arena, union-find."""

import numpy as np
import pytest

from repro.core import terms
from repro.core.rules import Program, Rule, parse_program, parse_rule
from repro.core.terms import Dictionary, SAME_AS, var
from repro.core.triples import TripleArena, pack, unpack
from repro.core.uf import (
    FrozenRho,
    clique_members,
    clique_sizes,
    compress_np,
    merge_pairs_jax,
    merge_pairs_np,
    split_cliques,
)


def test_dictionary_roundtrip():
    d = Dictionary()
    a = d.intern(":a")
    b = d.intern(":b")
    assert d.intern(":a") == a != b
    assert d.lookup(a) == ":a"
    assert d.id_of("owl:sameAs") == SAME_AS
    assert ":a" in d and ":zzz" not in d


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    spo = rng.integers(0, terms.MAX_ID, size=(1000, 3)).astype(np.int32)
    assert (unpack(pack(spo)) == spo).all()
    # packing is order-preserving lexicographically
    keys = pack(spo)
    order = np.argsort(keys)
    rows = spo[order]
    as_tuples = [tuple(r) for r in rows]
    assert as_tuples == sorted(as_tuples)


def test_arena_add_dedup_and_mark():
    a = TripleArena(capacity=2)
    added = a.add_batch(np.array([[1, 2, 3], [1, 2, 3], [4, 5, 6]], np.int32))
    assert added.shape[0] == 2
    assert a.total == 2 and a.unmarked == 2
    # re-adding is a no-op
    assert a.add_batch(np.array([[4, 5, 6]], np.int32)).shape[0] == 0
    # marking hides from matching but keeps the row (paper: mark, don't delete)
    a.mark_rows(np.array([0]))
    assert a.total == 2 and a.unmarked == 1
    assert not a.contains(np.array([[1, 2, 3]]))[0]
    assert a.contains(np.array([[4, 5, 6]]))[0]
    # growth across capacity boundary
    big = np.stack([np.arange(7, 107), np.full(100, 2), np.arange(7, 107)], axis=1)
    assert a.add_batch(big.astype(np.int32)).shape[0] == 100
    assert a.unmarked == 101


def test_rewrite_sweep_marks_and_returns():
    a = TripleArena()
    a.add_batch(np.array([[5, 2, 5], [7, 2, 8]], np.int32))
    rep = np.arange(10, dtype=np.int32)
    rep[7] = 3  # 7 merged into 3
    rw = a.rewrite_sweep(rep)
    assert rw.tolist() == [[3, 2, 8]]
    assert a.unmarked == 1  # <5,2,5> untouched, <7,2,8> marked
    assert a.total == 2


def test_union_find_min_hooking_deterministic():
    rep = np.arange(10, dtype=np.int32)
    pairs = np.array([[3, 7], [7, 9], [2, 9], [5, 4]], np.int32)
    rep1, n1 = merge_pairs_np(rep.copy(), pairs)
    # same pairs in any order give the same result
    rep2, n2 = merge_pairs_np(rep.copy(), pairs[::-1])
    assert (rep1 == rep2).all() and n1 == n2 == 4
    # clique {2,3,7,9} -> rep 2; {4,5} -> 4
    assert rep1[3] == rep1[7] == rep1[9] == rep1[2] == 2
    assert rep1[5] == rep1[4] == 4
    sizes = clique_sizes(rep1)
    assert sizes[2] == 4 and sizes[4] == 2 and sizes[0] == 1
    mem = clique_members(rep1)
    assert mem[2].tolist() == [2, 3, 7, 9]


def test_union_find_chain_and_cycle():
    rep = np.arange(6, dtype=np.int32)
    # chain 0-1, 1-2, 2-3, 3-0 (cycle) must not loop forever
    pairs = np.array([[0, 1], [1, 2], [2, 3], [3, 0]], np.int32)
    rep, n = merge_pairs_np(rep, pairs)
    assert (rep[:4] == 0).all() and n == 3


def test_union_find_jax_matches_np():
    rng = np.random.default_rng(1)
    n = 200
    for trial in range(5):
        pairs = rng.integers(0, n, size=(50, 2)).astype(np.int32)
        rep_np, _ = merge_pairs_np(np.arange(n, dtype=np.int32), pairs)
        valid = np.ones(pairs.shape[0], dtype=bool)
        # pad with garbage to exercise the mask
        pad = rng.integers(0, n, size=(13, 2)).astype(np.int32)
        pairs_j = np.concatenate([pairs, pad])
        valid_j = np.concatenate([valid, np.zeros(13, bool)])
        rep_j = np.asarray(
            merge_pairs_jax(
                np.arange(n, dtype=np.int32), pairs_j.astype(np.int32), valid_j
            )
        )
        assert (compress_np(rep_j) == rep_np).all(), trial


def test_split_cliques_semantics_pinned():
    """Regression pin for the serving refactor: suspect representatives (and
    only representatives) revert their whole clique to singletons; everything
    else — non-roots, singletons, empty suspect sets — is a no-op."""
    rep = compress_np(np.array([0, 0, 0, 3, 3, 5], np.int32))
    out = split_cliques(rep, np.array([0]))
    assert out.tolist() == [0, 1, 2, 3, 3, 5]
    assert rep.tolist() == [0, 0, 0, 3, 3, 5]  # input untouched (copy)
    # a non-representative member names no clique: no-op
    assert split_cliques(rep, np.array([1])).tolist() == rep.tolist()
    # a singleton representative: no-op
    assert split_cliques(rep, np.array([5])).tolist() == rep.tolist()
    # empty suspect set: no-op (identity object semantics not required)
    assert split_cliques(rep, np.zeros(0, np.int64)).tolist() == rep.tolist()
    # splitting every clique yields the identity map
    assert split_cliques(rep, np.array([0, 3])).tolist() == list(range(6))


def test_epoch_ok_tombstone_visibility_pinned():
    """Regression pin for the serving refactor: the tombstone predicates
    match the PRE-deletion store (tombstoned rows stay join candidates, like
    DRed matching deleted facts against T), while the forward predicates
    ignore ``tomb`` entirely and see only live epochs."""
    import jax.numpy as jnp

    from repro.core.engine_jax import (
        PRED_ALL,
        PRED_DELTA,
        PRED_OLD,
        PRED_TDELTA,
        PRED_TSTORE,
        _epoch_ok,
    )

    # rows: free, old live, marked, tombstoned wave 1, fresh live
    epoch = jnp.asarray([-1, 0, 1, 2, 2])
    marked = jnp.asarray([False, False, True, False, False])
    tomb = jnp.asarray([-1, 0, 1, 1, -1])
    r = 2

    def ok(pred):
        return np.asarray(_epoch_ok(epoch, marked, tomb, r, pred)).tolist()

    # pre-deletion store: every unmarked, allocated row — INCLUDING rows
    # already tombstoned this pass
    assert ok(PRED_TSTORE) == [False, True, False, True, True]
    # wave delta: tombstoned exactly in wave r-1
    assert ok(PRED_TDELTA) == [False, False, False, True, False]
    # forward discipline is blind to tombstones (the tomb==-1 invariant is
    # restored before any forward round runs)
    assert ok(PRED_OLD) == [False, True, False, False, False]
    assert ok(PRED_DELTA) == [False, False, False, False, False]  # row 2 marked
    assert ok(PRED_ALL) == [False, True, False, False, False]


def test_frozen_rho_view_matches_uf_helpers():
    raw = np.array([0, 0, 1, 3, 3, 5], np.int32)  # 2 -> 1 -> 0 chain
    fr = FrozenRho(raw)
    ref = compress_np(raw)
    assert (fr.rep == ref).all()
    assert not fr.rep.flags.writeable
    assert (fr.sizes == clique_sizes(ref)).all()
    want_members = clique_members(ref)
    assert set(fr.members) == set(want_members)
    for k, v in want_members.items():
        assert fr.members[k].tolist() == v.tolist()
    # the expansion tables are cached, not recomputed per query
    assert fr.members is fr.members and fr.sizes is fr.sizes
    assert fr.normalise(np.array([2, 4])).tolist() == [0, 3]
    assert len(fr) == 6


def test_rule_parse_and_rewrite():
    d = Dictionary()
    r = parse_rule("(?x, owl:sameAs, :USA) <- (:Obama, :presidentOf, ?x)", d)
    assert r.head[0] == var(1) and r.head[1] == SAME_AS
    rep = np.arange(len(d), dtype=np.int32)
    rep[d.id_of(":USA")] = d.id_of(":Obama")
    rr = r.rewrite(rep)
    assert rr.head[2] == d.id_of(":Obama")
    assert rr.body == r.body  # body had no :USA
    prog, changed = Program([r]).rewrite(rep)
    assert changed == [0]
    prog2, changed2 = prog.rewrite(rep)
    assert changed2 == []


def test_unsafe_rule_rejected():
    with pytest.raises(ValueError):
        Rule((var(1), SAME_AS, var(2)), ((var(1), 5, 6),))


def test_probe_boundary_key_no_sentinel_alias():
    """Satellite bugfix pin: an INVALID probe slot must never hit a store
    row, even when the garbage in the slot packs to KEY_MAX - 1 — the key
    of <2^21-1, 2^21-1, 2^21-2>, which raw (non-dictionary) engine inputs
    can legitimately contain.  The old code parked invalid probes at the
    KEY_MAX - 1 sentinel, so such a row was spuriously matched (and e.g.
    tombstoned by _seed_tombs)."""
    import jax.numpy as jnp

    from repro.core.engine_jax import I32, KEY_MAX, enable_x64
    from repro.core.incremental_spmd import _probe_index

    m = (1 << 21) - 1
    boundary = np.asarray([m, m, m - 1], np.int32)  # packs to KEY_MAX - 1
    with enable_x64():
        spo = jnp.asarray(np.stack([[1, 2, 3], boundary]), I32)
        keys = np.array([pack(np.asarray([[1, 2, 3]], np.int64))[0],
                         np.int64((1 << 63) - 2)])
        order = np.argsort(keys)
        sorted_keys = jnp.asarray(keys[order])
        sort_perm = jnp.asarray(order.astype(np.int32))
        select = jnp.asarray([True, True])
        # one valid query for the boundary row, one INVALID slot holding the
        # exact same garbage triple
        queries = jnp.asarray(np.stack([boundary, boundary]), I32)
        qvalid = jnp.asarray([True, False])
        rows, hit = _probe_index(sorted_keys, sort_perm, select, queries, qvalid)
        assert np.asarray(hit).tolist() == [True, False]
        assert int(np.asarray(rows)[0]) == 1
        assert int(np.asarray(sorted_keys)[1]) == (1 << 63) - 2  # KEY_MAX - 1 real


def test_probe_respects_select_mask():
    """A probe hit on a live row excluded by ``select`` (e.g. already
    tombstoned) reports no hit."""
    import jax.numpy as jnp

    from repro.core.engine_jax import I32, KEY_MAX, enable_x64
    from repro.core.incremental_spmd import _probe_index

    with enable_x64():
        rows_np = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
        keys = np.sort(pack(rows_np.astype(np.int64)))
        sorted_keys = jnp.asarray(keys)
        sort_perm = jnp.asarray(np.argsort(pack(rows_np.astype(np.int64))).astype(np.int32))
        queries = jnp.asarray(rows_np, I32)
        qvalid = jnp.asarray([True, True])
        rows, hit = _probe_index(
            sorted_keys, sort_perm, jnp.asarray([False, True]), queries, qvalid
        )
        assert np.asarray(hit).tolist() == [False, True]


def test_compact_is_stable_partition():
    """_compact packs valid rows front, stably, without sorting; overflow
    flags valid rows beyond cap; tail rows stay masked."""
    import jax.numpy as jnp

    from repro.core.engine_jax import _compact, enable_x64

    with enable_x64():
        col = jnp.asarray(np.arange(10, dtype=np.int32))
        valid = jnp.asarray([False, True, True, False, True, False, True, True, False, True])
        out, ov_valid, overflow = _compact({"c": col}, valid, 8)
        assert np.asarray(out["c"])[np.asarray(ov_valid)].tolist() == [1, 2, 4, 6, 7, 9]
        assert not bool(overflow)
        out, ov_valid, overflow = _compact({"c": col}, valid, 4)
        assert np.asarray(out["c"])[np.asarray(ov_valid)].tolist() == [1, 2, 4, 6]
        assert bool(overflow)


def test_index_invariant_report_catches_corruption():
    """The invariant checker itself must flag a broken index."""
    from repro.core.engine_jax import JaxEngine, index_invariant_report
    from repro.data.datasets import pex

    facts, prog, dic = pex()
    eng = JaxEngine(dic.n_resources, capacity=64, bind_cap=64, out_cap=64,
                    rewrite_cap=64)
    state = eng.materialise_state(facts, prog)
    assert index_invariant_report(state) == []
    import jax.numpy as jnp

    from repro.core.engine_jax import enable_x64

    with enable_x64():
        state.sorted_keys = state.sorted_keys.at[0].set(jnp.int64(12345))
    assert index_invariant_report(state) != []


def test_delta_growth_clamped_and_eviction_scoped():
    """Review pins: (1) delta caps never double past their wide caps (the
    periodic narrow probe must not re-grow + recompile forever on
    store-scale workloads); (2) eviction after growth is family-precise
    for tagged keys but still value-matches derived-width keys (padbuf /
    process / squeeze), which would otherwise leak executables."""
    from repro.core.engine_jax import JaxEngine

    eng = JaxEngine(10, capacity=64, bind_cap=1 << 13, out_cap=1 << 13,
                    rewrite_cap=1 << 13)
    assert eng.delta_bind == 1 << 13  # floor == wide here
    eng._grow_for("delta_bind")
    assert eng.delta_bind == 1 << 13  # clamped at bind_cap
    assert eng._delta_fallback
    eng._grow_for("bind")  # wide grows (fallback active -> x4)
    assert eng.bind_cap == 1 << 15
    eng._grow_for("delta_bind")  # now below wide again: doubles
    assert eng.delta_bind == 1 << 14

    # eviction scoping
    eng._fns = {
        ("plan", 0, 0, "delta", (), (), ("bind", 1 << 14), ("out", 1 << 13)): 1,
        ("plan", 0, 0, "full", (), (), ("bind", 1 << 15), ("out", 1 << 13)): 2,
        ("padbuf", 1 << 14): 3,
        ("process", 1 << 14, ("rewrite", 4096), ("route", None),
         ("out", 1 << 13), ("pair", 4096)): 4,
        ("squeeze", 123, ("out", 1 << 13)): 5,
    }
    eng._grow_for("delta_bind")  # 1<<14 -> 1<<15, records ("bind", 1<<14)
    assert eng.delta_bind == 1 << 15
    keys = set(eng._fns.values())
    # the delta-bind plan fn and the derived-width padbuf/process entries
    # at the outgrown value are gone; the wide plan fn and unrelated
    # squeeze survive (no ("out", ...) growth happened)
    assert keys == {2, 5}


def test_member_rejects_key_max_padding_match():
    """The all-max-ID triple packs to KEY_MAX (the padding sentinel, reserved);
    _member must not report it present by matching index padding."""
    import jax.numpy as jnp

    from repro.core.engine_jax import I32, enable_x64
    from repro.core.incremental_spmd import _member

    m = (1 << 21) - 1
    with enable_x64():
        sorted_keys = jnp.asarray(
            np.array([pack(np.asarray([[1, 2, 3]], np.int64))[0],
                      np.int64((1 << 63) - 1)])  # one live key + padding
        )
        q = jnp.asarray(np.stack([[1, 2, 3], [m, m, m]]), I32)
        qv = jnp.asarray([True, True])
        hit = _member(sorted_keys, q, qv, axis=None)
        assert np.asarray(hit).tolist() == [True, False]


def test_head_may_rederive_pre_post_split_mapping():
    """The ISSUE 5 satellite-2 corner, pinned at the unit level: overdelete
    masks (and the extracted tombstone rows) hold PRE-split normal forms,
    while the rule is rewritten under the POST-split rho — a head constant
    that is a non-representative member of a split clique must be collapsed
    through the pre-deletion rho before matching.  The naive post-split
    check would skip the rule and lose a restorable fact."""
    from repro.core.incremental_spmd import _head_bindings, _head_may_rederive

    # pre-split clique {1, 2, 3} with representative 1; resources 4 and 5
    # are singletons.  Post-split, constant 3 reverts to itself.
    rep_old = np.asarray([0, 1, 1, 1, 4, 5], np.int32)
    # the overdeleted instance, normal under the PRE-split rho: its object
    # slot holds the old representative 1, not the member 3
    od = np.asarray([[5, 4, 1]], np.int32)
    od_mask = np.zeros((3, 6), bool)
    for pos in range(3):
        od_mask[pos][od[:, pos]] = True
    rule = Rule((-1, 4, 3), ((-1, 5, -2),))  # head (?x, :p4, :c3) post-split

    assert _head_may_rederive(rule, od_mask, rep_old)
    assert not od_mask[2][3]  # the naive post-split lookup would say False

    # the exact row-wise filter agrees and extracts the ?x binding
    bind = _head_bindings(rule, od, rep_old)
    assert bind.tolist() == [[5]]


def test_head_bindings_eq_vars_dedup_and_const_head():
    """_head_bindings semantics: repeated head variables filter row-wise,
    bindings deduplicate, mismatching constants drop rows, and a
    variable-free head returns None (the whole-rule fallback signal)."""
    from repro.core.incremental_spmd import _head_bindings

    rep = np.arange(12, dtype=np.int32)
    od = np.asarray(
        [[7, 4, 7], [7, 4, 8], [9, 4, 9], [7, 4, 7], [7, 5, 7]], np.int32
    )
    # head (?x, :p4, ?x): only rows with s == o and p == 4, deduplicated
    rule_eq = Rule((-1, 4, -1), ((-1, 5, -2),))
    assert _head_bindings(rule_eq, od, rep).tolist() == [[7], [9]]
    # head (?x, :p4, ?y): two-column bindings, deduplicated
    rule_xy = Rule((-1, 4, -2), ((-1, 5, -2),))
    assert _head_bindings(rule_xy, od, rep).tolist() == [
        [7, 7], [7, 8], [9, 9],
    ]
    # no overdeleted row matches p = 6: empty binding table
    rule_p6 = Rule((-1, 6, -2), ((-1, 5, -2),))
    assert _head_bindings(rule_p6, od, rep).shape == (0, 2)
    # variable-free head: no instance constraint exists
    rule_const = Rule((7, 4, 7), ((-1, 5, -2),))
    assert _head_bindings(rule_const, od, rep) is None


def test_build_rederive_plan_orders_bound_atoms_first():
    """The head-bound plan chains backward: atoms sharing a variable with
    the bound set come first (so their fixed positions form index-prefix
    range probes), and every atom matches the surviving store
    (PRED_TSTORE)."""
    from repro.core.engine_jax import PRED_TSTORE, build_rederive_plan

    # head (?x, 4, ?z) <- (?y, 5, ?z) & (?x, 5, ?y): written delta-first
    # order starts at an atom NOT sharing ?x; the rederive plan must pick
    # the ?z-sharing atom anyway (both share a head var here), then chain
    rule = Rule((-1, 4, -3), ((-2, 5, -3), (-1, 5, -2)))
    plan, head_vars = build_rederive_plan(rule)
    assert head_vars == (-1, -3)
    assert [s.pred for s in plan] == [PRED_TSTORE, PRED_TSTORE]
    # first picked atom binds a head var; the second is fully chained
    first, second = plan
    assert any(v in (-1, -3) for v, _ in first.bound_items)
    assert {v for v, _ in second.bound_items} >= {-2}

    # a body atom with NO head-var overlap anywhere still gets a plan
    rule2 = Rule((-1, 4, -1), ((-2, 5, -3), (-1, 6, -1)))
    plan2, hv2 = build_rederive_plan(rule2)
    assert hv2 == (-1,)
    # the ?x atom is evaluated first despite being written second
    assert plan2[0].index == 1
