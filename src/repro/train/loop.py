"""Fault-tolerant training driver.

What a 1000+-node trainer needs and where it lives here:

  * **checkpoint/restart** — ``Trainer.run`` checkpoints every
    ``ckpt_every`` steps (async writer, atomic rename) and ``resume()``s
    from the newest complete step after a crash; the data pipeline is
    deterministic-per-step so only the step counter is stored
    (tests/test_train_loop.py kills a run mid-flight and restarts it,
    asserting bit-identical losses vs an uninterrupted run),
  * **elastic re-mesh** — restore places the global arrays onto a NEW mesh's
    shardings (tests/test_elastic.py restores a 4-way run onto 2 devices),
  * **straggler mitigation** — per-step wall times feed an EWMA deadline; a
    step exceeding ``straggler_factor`` x EWMA fires ``on_straggler`` (at
    scale: trigger checkpoint-and-rebalance; here: recorded + tested hook),
  * **heartbeat** — a liveness file updated every step lets an external
    supervisor distinguish slow from dead (``heartbeat_path``),
  * **cross-pod gradient compression** — optional int8 error-feedback
    exchange over the ``pod`` axis (optim/compression.py), wrapped in
    shard_map when the mesh has a pod axis,
  * **loss-scale/NaN guard** — a non-finite loss skips the update (keeps
    params/opt), counts the skip, and re-tries the next batch; persistent
    NaNs (> ``max_nan_skips`` consecutive) abort.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, latest_step, restore_checkpoint
from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    async_ckpt: bool = True
    lr: float = 3e-4
    straggler_factor: float = 3.0
    heartbeat_path: str | None = None
    max_nan_skips: int = 5
    log_every: int = 10


class Trainer:
    """Drives (loss_fn, params, batches) to ``n_steps`` with FT machinery.

    ``loss_fn(params, batch) -> scalar``; ``batch_fn(step) -> batch`` must be
    deterministic in ``step`` (the restart contract).
    """

    def __init__(
        self,
        loss_fn: Callable,
        init_params,
        batch_fn: Callable[[int], dict],
        cfg: TrainConfig,
        shardings=None,
        mesh=None,
        on_straggler: Callable[[int, float], None] | None = None,
    ):
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.mesh = mesh
        self.on_straggler = on_straggler
        self.params = init_params
        self.opt = adamw_init(init_params)
        self.shardings = shardings
        self.step = 0
        self.nan_skips = 0
        self.straggler_events: list[tuple[int, float]] = []
        self.losses: list[float] = []
        self._mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep, async_save=cfg.async_ckpt)

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt, gn = adamw_update(params, grads, opt, lr=cfg.lr)
            ok = jnp.isfinite(loss)
            # NaN guard: keep old state when the loss is non-finite
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params
            )
            new_opt = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_opt, opt)
            return new_params, new_opt, loss, gn

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    # -- restart ----------------------------------------------------------
    def resume(self) -> bool:
        """Restore the newest checkpoint if present.  Returns True if resumed."""
        if latest_step(self.cfg.ckpt_dir) is None:
            return False
        state = {"params": self.params, "opt": self.opt}
        tree, aux, step = restore_checkpoint(
            self.cfg.ckpt_dir, state, shardings=self.shardings
        )
        self.params, self.opt = tree["params"], tree["opt"]
        self.step = int(aux["next_step"])
        return True

    def _checkpoint(self):
        self._mgr.save(
            self.step,
            {"params": self.params, "opt": self.opt},
            aux={"next_step": self.step},
        )

    # -- main loop --------------------------------------------------------
    def run(self, until: int | None = None):
        until = until if until is not None else self.cfg.n_steps
        ewma = None
        while self.step < until:
            t0 = time.time()
            batch = self.batch_fn(self.step)
            batch = jax.tree.map(jnp.asarray, batch)
            self.params, self.opt, loss, gn = self._step_fn(self.params, self.opt, batch)
            loss = float(loss)
            if not np.isfinite(loss):
                self.nan_skips += 1
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise FloatingPointError(
                        f"{self.nan_skips} consecutive non-finite losses at step {self.step}"
                    )
            else:
                self.nan_skips = 0
            self.losses.append(loss)
            dt = time.time() - t0

            # straggler detection (EWMA of step time)
            if ewma is None:
                ewma = dt
            if dt > self.cfg.straggler_factor * ewma and self.step > 2:
                self.straggler_events.append((self.step, dt))
                if self.on_straggler:
                    self.on_straggler(self.step, dt)
            ewma = 0.9 * ewma + 0.1 * dt

            # heartbeat for the external supervisor
            if self.cfg.heartbeat_path:
                os.makedirs(
                    os.path.dirname(os.path.abspath(self.cfg.heartbeat_path)),
                    exist_ok=True,
                )
                with open(self.cfg.heartbeat_path, "w") as f:
                    f.write(f"{self.step} {time.time()}\n")

            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self._checkpoint()
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"[train] step={self.step} loss={loss:.4f} dt={dt*1e3:.1f}ms")
        self._checkpoint()
        self._mgr.wait()
        return self.losses

    def close(self):
        self._mgr.close()
