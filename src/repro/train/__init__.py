from .loop import TrainConfig, Trainer

__all__ = ["TrainConfig", "Trainer"]
