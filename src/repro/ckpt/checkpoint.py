"""Atomic, async, mesh-elastic checkpointing.

Layout (one directory per step):

    <dir>/step_000042.tmp-<pid>/   — being written
        manifest.json              — keypaths, shapes, dtypes, aux state
        arrays.npz                 — one entry per leaf (global arrays)
    <dir>/step_000042/             — atomically renamed when complete

Properties needed at scale and how they are provided here:

  * **atomicity** — write into a ``.tmp-<pid>`` dir, fsync, ``os.rename``;
    a crashed writer never corrupts the latest checkpoint, restore picks the
    newest COMPLETE step directory,
  * **async** — ``CheckpointManager(async_save=True)`` snapshots the pytree
    to host memory synchronously (cheap) and writes on a daemon thread so
    the train loop never blocks on the filesystem,
  * **elasticity** — arrays are stored as GLOBAL values; ``restore`` places
    them onto an arbitrary target sharding pytree (``jax.device_put``), so a
    job restarted on a different mesh shape resharding-restores transparently
    (tests/test_elastic.py),
  * **retention** — keeps the newest ``keep`` checkpoints, deletes older
    ones only after a successful save (never drops the last good one).

On a real multi-host pod each host writes its address-able shards and the
manifest records the global shape; the single-process layout here is the
degenerate one-host case of that scheme.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# dtypes np.savez cannot serialise natively -> stored as a same-width uint
# view, reconstructed from the manifest dtype on restore
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _encode(a: np.ndarray) -> np.ndarray:
    v = _VIEW_AS.get(str(a.dtype))
    return a.view(v) if v is not None else a


def _decode(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _VIEW_AS:
        return a.view(getattr(ml_dtypes, dtype))
    return a


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(directory: str, step: int, tree, aux: dict | None = None) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, vals, _ = _flatten(tree)
    vals = [np.asarray(v) for v in vals]
    arrays = {f"a{i}": _encode(v) for i, v in enumerate(vals)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "shapes": [list(np.shape(v)) for v in vals],
        "dtypes": [str(np.asarray(v).dtype) for v in vals],
        "aux": aux or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, target, step: int | None = None, shardings=None
):
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — arrays are placed onto it (elastic re-mesh restore).
    Returns (tree, aux, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    vals = [
        _decode(data[f"a{i}"], manifest["dtypes"][i])
        for i in range(len(manifest["keys"]))
    ]

    keys_t, vals_t, treedef = _flatten(target)
    if keys_t != manifest["keys"]:
        raise ValueError(
            f"checkpoint structure mismatch: {set(manifest['keys']) ^ set(keys_t)}"
        )
    if shardings is not None:
        sh_flat = jax.tree_util.tree_leaves(shardings)
        vals = [jax.device_put(v, s) for v, s in zip(vals, sh_flat)]
    else:
        vals = [
            jax.numpy.asarray(v, dtype=t.dtype) if hasattr(t, "dtype") else v
            for v, t in zip(vals, vals_t)
        ]
    return jax.tree_util.tree_unflatten(treedef, vals), manifest["aux"], step


class CheckpointManager:
    """Retention + optional async writer around ``save_checkpoint``."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._q: queue.Queue = queue.Queue()
        self._err: list[BaseException] = []
        self._thread = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, aux = item
            try:
                save_checkpoint(self.directory, step, tree, aux)
                self._gc()
            except BaseException as e:  # surfaced on next save()/wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n[5:])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"))

    def save(self, step: int, tree, aux: dict | None = None):
        if self._err:
            raise self._err.pop()
        if self.async_save:
            # device->host snapshot now; disk write on the worker thread
            host = jax.tree.map(lambda v: np.asarray(v), tree)
            self._q.put((step, host, aux))
        else:
            save_checkpoint(self.directory, step, tree, aux)
            self._gc()

    def wait(self):
        if self.async_save:
            self._q.join()
        if self._err:
            raise self._err.pop()

    def close(self):
        if self.async_save and self._thread is not None:
            self.wait()
            self._q.put(None)
            self._thread.join()
