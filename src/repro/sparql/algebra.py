"""Minimal SPARQL algebra over rewritten triples (paper §5).

A query is a basic graph pattern plus an ordered list of post-steps
(FILTER / BIND) and a final projection.  Enough expressiveness to exercise
the paper's two correctness hazards:

  * bag semantics — projected-out variables must contribute clique-size
    multiplicities,
  * builtins — arguments must be expanded *before* the builtin runs, and
    expanded variables must not be multiplied again at projection.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.rules import _ATOM_RE, parse_term
from repro.core.terms import Dictionary

Atom = tuple[int, int, int]


@dataclass(frozen=True)
class Bind:
    """BIND(fn(?src) AS ?dst); fn is a builtin over the *resource name*."""

    fn: str  # 'STR' | 'UCASE'
    src: int
    dst: int


@dataclass(frozen=True)
class FilterEq:
    """FILTER(?var = <resource>) — resource-level equality (pre-expansion it
    must be evaluated on expanded bindings, like a builtin)."""

    var: int
    value: int


@dataclass
class Query:
    patterns: list[Atom]
    steps: list = field(default_factory=list)
    select: list[int] = field(default_factory=list)
    distinct: bool = False

    @staticmethod
    def parse(text: str, dic: Dictionary) -> "Query":
        """Parse ``SELECT ?x ?y WHERE { (s,p,o) . (s,p,o) }`` mini-syntax."""
        head, _, body = text.partition("WHERE")
        varmap: dict[str, int] = {}
        patterns = [
            tuple(parse_term(t, dic, varmap) for t in m)
            for m in _ATOM_RE.findall(body)
        ]
        select = [parse_term(tok, dic, varmap) for tok in head.split() if tok.startswith("?")]
        distinct = "DISTINCT" in head
        return Query(patterns, [], select, distinct)

    def bind(self, fn: str, src: int, dst: int) -> "Query":
        self.steps.append(Bind(fn, src, dst))
        return self

    def filter_eq(self, var: int, value: int) -> "Query":
        self.steps.append(FilterEq(var, value))
        return self
