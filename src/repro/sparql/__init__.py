from .algebra import Query
from .executor import evaluate, evaluate_at, evaluate_naive

__all__ = ["Query", "evaluate", "evaluate_at", "evaluate_naive"]
