from .algebra import Query
from .executor import evaluate, evaluate_naive

__all__ = ["Query", "evaluate", "evaluate_naive"]
