"""SPARQL evaluation over (T, rho) with correct bag semantics (paper §5).

``evaluate`` implements the paper's strategy:
  1. normalise the query: rho(Q) (constants -> representatives),
  2. match the BGP against the succinct store T (small joins — the win),
  3. run FILTER/BIND steps, expanding their argument variables *first* and
     flagging them so they are not multiplied again later,
  4. project: every projected-out, still-unexpanded variable multiplies the
     answer multiplicity by its owl:sameAs-clique size,
  5. expand the retained, still-unexpanded variables into clique members.

``evaluate_naive`` is the strawman the paper §5 shows to be wrong (match
rho(Q), project, then post-hoc expansion of the answer set): it loses
multiplicities and produces wrong builtin results.  Kept for the tests and
benchmarks that reproduce the paper's argument.

Both evaluators accept either a raw representative array or a pre-frozen
:class:`repro.core.uf.FrozenRho` — serving hands the latter so the clique
expansion tables are computed once per maintenance epoch, not per query.
``evaluate_at`` answers against an epoch snapshot handle
(:class:`repro.core.engine_jax.StoreSnapshot`) instead of reading a live
arena, returning the epoch alongside the bag so callers can attribute every
answer to the completed fixpoint it was computed at (docs/serving.md).
"""

from __future__ import annotations

from collections import Counter

import numpy as np

from repro.core.seminaive import Bindings, join_atom
from repro.core.terms import is_var
from repro.core.uf import FrozenRho

from .algebra import Bind, FilterEq, Query


def _norm_const(t: int, rep: np.ndarray) -> int:
    """rho(t) for a query constant.

    A constant interned after this rho was frozen (a resource the serving
    epoch has never seen) is its own representative — a singleton — so the
    query stays answerable (empty match) instead of indexing out of range.
    """
    return int(rep[t]) if t < rep.shape[0] else int(t)


def _normalise_query(q: Query, rep: np.ndarray) -> Query:
    pats = [
        tuple(_norm_const(t, rep) if not is_var(t) else t for t in atom)
        for atom in q.patterns
    ]
    # steps pass through untouched: FILTER comparison values must NOT be
    # normalised (FILTER compares concrete resources, hence runs on
    # expanded bindings), and builtins operate on expanded resources too
    return Query(pats, list(q.steps), list(q.select), q.distinct)


def _match_bgp(patterns, triples: np.ndarray):
    b = Bindings.empty_universe()
    for atom in patterns:
        b, _ = join_atom(b, atom, triples)
        if b.nrows == 0:
            break
    return b


class _Solutions:
    """Columnar solution table with multiplicities and per-var expansion flags."""

    def __init__(self, bindings: Bindings):
        self.cols: dict[int, np.ndarray] = dict(bindings.cols)
        self.strs: dict[int, list[str]] = {}  # builtin outputs (host strings)
        self.mult = np.ones(bindings.nrows, dtype=np.int64)
        self.expanded: set[int] = set()

    @property
    def nrows(self) -> int:
        return self.mult.shape[0]

    def take(self, idx: np.ndarray) -> None:
        self.cols = {v: c[idx] for v, c in self.cols.items()}
        self.strs = {v: [s[i] for i in idx] for v, s in self.strs.items()}
        self.mult = self.mult[idx]

    def expand_var(self, v: int, rho: FrozenRho) -> None:
        """Replace each row by one row per clique member of row[v]."""
        if v in self.expanded or v not in self.cols:
            return
        idx, vals = rho.expand_ids(self.cols[v])
        self.take(idx)
        self.cols[v] = vals
        self.expanded.add(v)


def _rho_view(rep) -> FrozenRho:
    return rep if isinstance(rep, FrozenRho) else FrozenRho(rep)


def evaluate(
    q: Query,
    triples: np.ndarray,
    rep,
    dic,
) -> Counter:
    """Bag of answers: Counter mapping answer tuples (ordered by q.select).

    Answer atoms are resource names (via ``dic``) for resource vars and raw
    strings for builtin-produced vars.  ``rep`` is a representative array or
    a :class:`~repro.core.uf.FrozenRho` view.
    """
    rho = _rho_view(rep)
    qn = _normalise_query(q, rho.rep)
    sol = _Solutions(_match_bgp(qn.patterns, triples))
    return _finish(q, qn, sol, rho, dic)


def _finish(q: Query, qn: Query, sol: _Solutions, rho: FrozenRho, dic) -> Counter:
    """Steps + projection + clique expansion over a matched solution table.

    The tail of :func:`evaluate` after the BGP match — shared verbatim by
    the host matcher and the batched device matcher
    (:mod:`repro.sparql.batched`), so the two paths can only differ in how
    the BGP solution rows were produced, never in the bag semantics layered
    on top of them.
    """
    sizes = rho.sizes

    for step in qn.steps:
        if isinstance(step, Bind):
            # paper §5 Q2: expand *before* evaluating the builtin
            sol.expand_var(step.src, rho)
            names = [dic.lookup(int(x)) for x in sol.cols[step.src]]
            if step.fn == "STR":
                out = [n.lstrip(":") for n in names]
            elif step.fn == "UCASE":
                out = [n.lstrip(":").upper() for n in names]
            else:
                raise ValueError(f"unknown builtin {step.fn}")
            sol.strs[step.dst] = out
            sol.expanded.add(step.dst)
        elif isinstance(step, FilterEq):
            # comparisons see concrete resources: expand first
            sol.expand_var(step.var, rho)
            keep = np.flatnonzero(sol.cols[step.var] == step.value)
            sol.take(keep)

    # projection: projected-out unexpanded vars contribute clique sizes
    keep_vars = list(qn.select)
    for v in list(sol.cols):
        if v not in keep_vars and v not in sol.expanded:
            sol.mult = sol.mult * sizes[sol.cols[v]]
    # expand retained resource vars (unexpanded ones only)
    for v in keep_vars:
        if v in sol.cols:
            sol.expand_var(v, rho)

    if (not sol.strs and keep_vars and sol.nrows > 64
            and all(v in sol.cols for v in keep_vars)):
        # pure-resource answers with non-trivial bags: collapse duplicate
        # rows and look names up once per distinct id instead of once per
        # row — answer bags expand to clique x clique sizes, so the Python
        # per-row loop was the dominant cost of a served scan query.  Small
        # bags (point lookups) stay on the loop: its per-row cost undercuts
        # the fixed np.unique(axis=0) setup below the cutoff
        mat = np.stack([sol.cols[v] for v in keep_vars], axis=1)
        uniq, inv = np.unique(mat, axis=0, return_inverse=True)
        mults = np.zeros(uniq.shape[0], dtype=np.int64)
        np.add.at(mults, inv, sol.mult)
        names = {int(i): dic.lookup(int(i)) for i in np.unique(uniq)}
        out = Counter()
        for row, m in zip(uniq.tolist(), mults.tolist()):
            out[tuple(names[x] for x in row)] = m
    else:
        out = Counter()
        for i in range(sol.nrows):
            key = tuple(
                sol.strs[v][i] if v in sol.strs
                else dic.lookup(int(sol.cols[v][i]))
                for v in keep_vars
            )
            out[key] += int(sol.mult[i])
    if q.distinct:
        return Counter({k: 1 for k in out})
    return out


def evaluate_at(q: Query, snapshot, dic, naive: bool = False):
    """Answer ``q`` against an epoch-consistent snapshot handle.

    ``snapshot`` is any object with ``triples`` (host copy of the live
    normal-form store at some completed maintenance epoch), ``rho`` (a
    :class:`~repro.core.uf.FrozenRho`) and ``epoch`` — canonically
    :class:`repro.core.engine_jax.StoreSnapshot` (device-resident snapshots
    materialise their host copy lazily on first access here).  Returns
    ``(answers, epoch)``: the executor never touches the live arena, so a
    maintenance operation in flight on the owning state cannot leak a
    mid-round store into the answer (the ``as_of_epoch`` contract of
    :mod:`repro.serve.triple_store`).
    """
    fn = evaluate_naive if naive else evaluate
    return fn(q, snapshot.triples, snapshot.rho, dic), snapshot.epoch


def evaluate_naive(q: Query, triples: np.ndarray, rep, dic) -> Counter:
    """The incorrect strategy (paper §5): evaluate rho(Q) on T, run builtins
    on representatives, project, then post-hoc expand the answer set."""
    rho = _rho_view(rep)
    rep = rho.rep
    members = rho.members
    qn = _normalise_query(q, rep)
    sol = _Solutions(_match_bgp(qn.patterns, triples))
    for step in qn.steps:
        if isinstance(step, Bind):
            names = [dic.lookup(int(x)) for x in sol.cols[step.src]]
            sol.strs[step.dst] = [n.lstrip(":") for n in names]
        elif isinstance(step, FilterEq):
            keep = np.flatnonzero(sol.cols[step.var] == _norm_const(step.value, rep))
            sol.take(keep)
    out: Counter = Counter()
    keep_vars = list(qn.select)
    for i in range(sol.nrows):
        # post-hoc expansion: substitute representatives by clique members
        lists = []
        for v in keep_vars:
            if v in sol.strs:
                lists.append([sol.strs[v][i]])
            else:
                ms = members.get(int(sol.cols[v][i]), np.array([sol.cols[v][i]]))
                lists.append([dic.lookup(int(m)) for m in ms])
        import itertools

        for combo in itertools.product(*lists):
            out[tuple(combo)] += 1
    return out
