"""Batched (vmapped) BGP execution against device-resident snapshots.

The serving tier's query path.  The host executor answers one query at a
time through a numpy join; a standing service drains a *queue* of queries
per epoch, and most of them share a handful of BGP shapes (the
DaRLing-style workload :mod:`repro.data.generator` models).  This module
groups queued queries by **shape signature** — the BGP with variables
canonically renumbered and constants abstracted to slots — and evaluates
each group in ONE compiled call: the per-query matcher is built once per
shape and ``jax.vmap`` runs it over the batch axis of constant bindings,
the batch-many-small-state-machines idiom the ROADMAP names.

The matcher itself is the engine's index-probe join
(:func:`repro.core.engine_jax._expand_join_index`,
:func:`repro.kernels.bsearch.prefix_range_bounds`) re-targeted at a
published :class:`~repro.core.engine_jax.StoreSnapshot`: the snapshot keeps
the live rows in two sorted packed-key orders — ``(s,p,o)`` and
``(p,o,s)`` — so every atom whose bound positions form a prefix of either
order is two ``jnp.searchsorted`` calls plus a cumsum-enumerated gather,
never an arena-length scan or sort.  Atoms with no bound prefix under
either order make the whole query **non-batchable**: it falls back to the
host matcher against the snapshot's lazy host copy (correctness never
depends on batchability).  Per-query width overflow likewise falls back —
the flag rides out of the compiled call, so a pathological query can never
silently truncate its answer bag.

Everything *after* the BGP match — FILTER/BIND steps, projection
multiplicities, clique expansion — is the host executor's
:func:`repro.sparql.executor._finish`, shared verbatim, so the batched and
scalar paths can only differ in how solution rows are produced (the
differential tests pin that they don't differ at all).

Dispatches are tagged under the ``"query"`` phase and the compiled matcher
registers with the trace-audit inventory as the ``"bgp"`` family.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine_jax import I32, register_auditable
from repro.core.seminaive import Bindings
from repro.core.terms import is_var

from .algebra import Query
from .executor import _Solutions, _finish, _normalise_query, evaluate_at

with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    from jax.experimental import enable_x64

_MAXID = (1 << 21) - 1

# the two published key orders: position scan sequences matching the packing
# of StoreSnapshot.d_keys ((s<<42)|(p<<21)|o) and d_keys_pos ((p<<42)|(o<<21)|s)
_ORDERS = (("spo", (0, 1, 2)), ("pos", (1, 2, 0)))


# ---------------------------------------------------------------------------
# shape signatures and probe plans (static, per shape)
# ---------------------------------------------------------------------------

def shape_signature(patterns) -> tuple[tuple, dict[int, int]]:
    """Canonical BGP shape: vars renumbered by first occurrence, constants
    abstracted to occurrence slots.

    Queries sharing a signature share one compiled matcher; their constants
    become the vmapped batch axis.  Returns ``(sig, varmap)`` where
    ``varmap`` maps the query's actual var ids to canonical ids.
    """
    varmap: dict[int, int] = {}
    sig = []
    for atom in patterns:
        parts = []
        for t in atom:
            if is_var(t):
                if t not in varmap:
                    varmap[t] = len(varmap)
                parts.append(("v", varmap[t]))
            else:
                parts.append("c")
        sig.append(tuple(parts))
    return tuple(sig), varmap


@dataclass(frozen=True)
class _Probe:
    """One planned atom: a range probe against one key order + post-filters."""

    order: str          # "spo" | "pos" — which snapshot view to probe
    atom: int           # original atom index (labels only)
    prefix: tuple       # leading key positions: ("const", slot) | ("var", cv)
    post_consts: tuple  # ((triple_pos, slot), ...) consts outside the prefix
    post_bound: tuple   # ((triple_pos, cv), ...) bound vars outside the prefix
    eq_pairs: tuple     # ((pos_a, pos_b), ...) repeated vars within the atom
    free: tuple         # ((cv, triple_pos), ...) vars first bound here


@dataclass(frozen=True)
class BatchPlan:
    sig: tuple
    probes: tuple
    n_consts: int
    var_order: tuple    # canonical var ids in binding order


def build_plan(sig) -> BatchPlan | None:
    """Greedy longest-bound-prefix atom ordering over the two key orders.

    At each step pick the remaining atom with the longest prefix of bound
    positions (const or already-bound var) under either published order —
    ties break to the earlier atom and the primary ``(s,p,o)`` order.  BGP
    join bags are atom-order independent (each solution row is one choice
    of matching triple per atom), so reordering is free; an atom with no
    bound prefix at its turn makes the shape non-batchable (``None``) —
    the batched path has no cartesian/scan fallback by design.
    """
    const_slot: dict[tuple[int, int], int] = {}
    for i, atom in enumerate(sig):
        for pos, t in enumerate(atom):
            if t == "c":
                const_slot[(i, pos)] = len(const_slot)
    remaining = list(range(len(sig)))
    bound: set[int] = set()
    var_order: list[int] = []
    probes = []
    while remaining:
        best = None  # (prefix_len, atom, order_name, scan_seq)
        for i in remaining:
            for name, seq in _ORDERS:
                plen = 0
                for pos in seq:
                    t = sig[i][pos]
                    if t == "c" or t[1] in bound:
                        plen += 1
                    else:
                        break
                if best is None or plen > best[0]:
                    best = (plen, i, name, seq)
        plen, i, name, seq = best
        if plen == 0:
            return None
        atom = sig[i]
        prefix_pos = set(seq[:plen])
        prefix = tuple(
            ("const", const_slot[(i, pos)]) if atom[pos] == "c"
            else ("var", atom[pos][1])
            for pos in seq[:plen]
        )
        post_consts, post_bound, eq_pairs, free = [], [], [], []
        first_pos: dict[int, int] = {}
        for pos in (0, 1, 2):
            t = atom[pos]
            if t == "c":
                if pos not in prefix_pos:
                    post_consts.append((pos, const_slot[(i, pos)]))
            else:
                cv = t[1]
                if cv in first_pos:
                    eq_pairs.append((first_pos[cv], pos))
                else:
                    first_pos[cv] = pos
                    if cv in bound:
                        if pos not in prefix_pos:
                            post_bound.append((pos, cv))
                    else:
                        free.append((cv, pos))
        probes.append(_Probe(
            name, i, prefix,
            tuple(post_consts), tuple(post_bound), tuple(eq_pairs),
            tuple(free),
        ))
        for cv, _ in free:
            bound.add(cv)
            var_order.append(cv)
        remaining.remove(i)
    return BatchPlan(sig, tuple(probes), len(const_slot), tuple(var_order))


# ---------------------------------------------------------------------------
# the compiled matcher (one query; vmapped over the batch axis)
# ---------------------------------------------------------------------------

def _pack_parts(parts) -> jnp.ndarray:
    key = jnp.zeros(parts[0].shape, dtype=jnp.int64)
    for c in parts:
        key = (key << 21) | c.astype(jnp.int64)
    return key


def _bgp_one(probes, var_order, W: int,
             d_tri, d_keys, d_tri_pos, d_keys_pos, consts):
    """Match one query's BGP against a published snapshot; width-``W`` table.

    The binding table starts as the single empty substitution and each probe
    expands it like :func:`repro.core.engine_jax._expand_join_index`: pack
    per-row lo/hi prefix keys (zeros / MAXID beyond the prefix), two
    ``searchsorted`` range probes, a cumsum-enumerated gather of the
    matching rows, then mask-level post-filters for non-prefix constants,
    bound vars and repeated-var equality.  KEY_MAX padding rows sort behind
    every real key, so live-row bounds need no explicit ``n_live`` argument.
    A step whose true output exceeds ``W`` raises the per-query overflow
    flag — the caller falls back to the host matcher, never truncates.

    Two cost cuts versus the naive form (they set the batched-vs-scalar
    throughput ratio):

      * the FIRST probe's prefix is all constants by construction (nothing
        is bound yet), so its range is found by two *scalar* binary
        searches and enumerated by a plain range gather — no W-point
        searchsorted against the key array;
      * later probes assign output slots to binding rows with a
        scatter+cumsum over the exclusive offsets (``seg = cumsum(marks)-1``)
        instead of a W-point binary search into ``cum`` — O(W) work, and
        empty rows are skipped because their mark lands on the next row's
        start offset.
    """
    j = jnp.arange(W)
    cols: dict[int, jnp.ndarray] = {}
    overflow = jnp.zeros((), bool)

    pr0 = probes[0]
    keys = d_keys if pr0.order == "spo" else d_keys_pos
    tri = d_tri if pr0.order == "spo" else d_tri_pos
    lo_parts = [consts[ref].astype(jnp.int64) for _k, ref in pr0.prefix]
    hi_parts = list(lo_parts)
    for _ in range(3 - len(pr0.prefix)):
        lo_parts.append(jnp.zeros((), jnp.int64))
        hi_parts.append(jnp.full((), _MAXID, jnp.int64))
    lo0 = jnp.searchsorted(keys, _pack_parts(lo_parts), side="left")
    hi0 = jnp.searchsorted(keys, _pack_parts(hi_parts), side="right")
    n0 = jnp.maximum(hi0 - lo0, 0)
    src = jnp.clip(lo0 + j, 0, keys.shape[0] - 1)
    rows = tri[src]
    ok = j < n0
    for pos, slot in pr0.post_consts:
        ok = ok & (rows[:, pos] == consts[slot])
    for a, b in pr0.eq_pairs:
        ok = ok & (rows[:, a] == rows[:, b])
    for cv, pos in pr0.free:
        cols[cv] = jnp.where(ok, rows[:, pos], 0)
    overflow = overflow | (n0 > W)
    valid = ok

    for pr in probes[1:]:
        keys = d_keys if pr.order == "spo" else d_keys_pos
        tri = d_tri if pr.order == "spo" else d_tri_pos
        lo_parts, hi_parts = [], []
        for kind, ref in pr.prefix:
            col = (jnp.broadcast_to(consts[ref].astype(jnp.int64), (W,))
                   if kind == "const" else cols[ref].astype(jnp.int64))
            lo_parts.append(col)
            hi_parts.append(col)
        for _ in range(3 - len(pr.prefix)):
            lo_parts.append(jnp.zeros((W,), jnp.int64))
            hi_parts.append(jnp.full((W,), _MAXID, jnp.int64))
        lo = jnp.searchsorted(keys, _pack_parts(lo_parts), side="left")
        hi = jnp.searchsorted(keys, _pack_parts(hi_parts), side="right")
        counts = jnp.where(valid, jnp.maximum(hi - lo, 0), 0)
        cum = jnp.cumsum(counts) - counts  # exclusive
        total = counts.sum()
        marks = jnp.zeros((W,), I32).at[cum].add(
            1, mode="drop", indices_are_sorted=True
        )
        seg = jnp.cumsum(marks) - 1
        within = j - cum[seg]
        src = jnp.clip(lo[seg] + within, 0, keys.shape[0] - 1)
        rows = tri[src]
        ok = (j < total) & valid[seg]
        for pos, slot in pr.post_consts:
            ok = ok & (rows[:, pos] == consts[slot])
        for pos, cv in pr.post_bound:
            ok = ok & (rows[:, pos] == cols[cv][seg])
        for a, b in pr.eq_pairs:
            ok = ok & (rows[:, a] == rows[:, b])
        new_cols = {cv: jnp.where(ok, c[seg], 0) for cv, c in cols.items()}
        for cv, pos in pr.free:
            new_cols[cv] = jnp.where(ok, rows[:, pos], 0)
        overflow = overflow | (total > W)
        cols, valid = new_cols, ok
    if var_order:
        out = jnp.stack([cols[cv] for cv in var_order])
    else:
        out = jnp.zeros((1, W), I32)  # all-const BGP: validity carries it
    return out.astype(I32), valid, overflow


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# the batch executor (host orchestration)
# ---------------------------------------------------------------------------

class BatchedExecutor:
    """Drain a query list against one snapshot in grouped vmapped dispatches.

    Owns the per-shape plan cache and the policy knobs; the compiled
    matchers live in the *engine's* fn cache (keys
    ``("bgp", sig, B_pad, W, N)``) under normal dispatch accounting, tagged
    with the ``"query"`` phase.  ``run`` preserves input order and returns
    ``(answers, epoch)`` per query, exactly like
    :func:`repro.sparql.executor.evaluate_at` — host fallback (non-batchable
    shape, short group, width overflow, host-only snapshot) is invisible in
    the results.  Thread-wise ``run`` is called by one drain at a time (the
    scheduler serialises query drains); the stats dict is advisory.
    """

    def __init__(self, engine, width: int = 4096, min_batch: int = 2,
                 max_batch: int = 256):
        self.engine = engine
        self.width = width
        self.min_batch = max(int(min_batch), 1)
        self.max_batch = max(int(max_batch), 1)
        self._plans: dict[tuple, BatchPlan | None] = {}
        self.stats = {"batched": 0, "fallback": 0, "overflow": 0, "groups": 0}

    def _plan(self, sig) -> BatchPlan | None:
        if sig not in self._plans:
            self._plans[sig] = build_plan(sig)
        return self._plans[sig]

    def run(self, queries: list[Query], snapshot, dic) -> list:
        results: list = [None] * len(queries)
        if not queries:
            return results
        if not getattr(snapshot, "on_device", False):
            for i, q in enumerate(queries):
                results[i] = evaluate_at(q, snapshot, dic)
            self.stats["fallback"] += len(queries)
            return results
        rep = snapshot.rho.rep
        prepared: list = [None] * len(queries)
        groups: dict[tuple, list[int]] = {}
        host: list[int] = []
        for i, q in enumerate(queries):
            qn = _normalise_query(q, rep)
            sig, varmap = shape_signature(qn.patterns)
            if self._plan(sig) is None:
                host.append(i)
                continue
            prepared[i] = (qn, varmap)
            groups.setdefault(sig, []).append(i)
        for sig, idxs in list(groups.items()):
            if len(idxs) < self.min_batch:  # batching buys nothing; skip compile
                host.extend(idxs)
                del groups[sig]
        for i in host:
            results[i] = evaluate_at(queries[i], snapshot, dic)
            self.stats["fallback"] += 1
        for sig, idxs in groups.items():
            for at in range(0, len(idxs), self.max_batch):
                self._run_group(
                    sig, idxs[at:at + self.max_batch], prepared,
                    queries, snapshot, dic, results,
                )
        return results

    def _run_group(self, sig, idxs, prepared, queries, snapshot, dic, results):
        plan = self._plans[sig]
        B_pad = _pow2(len(idxs))
        consts = np.zeros((B_pad, max(plan.n_consts, 1)), np.int32)
        for row, i in enumerate(idxs):
            qn, _ = prepared[i]
            cs = [t for atom in qn.patterns for t in atom if not is_var(t)]
            if cs:
                consts[row] = cs
        eng = self.engine
        key = ("bgp", sig, B_pad, self.width, int(snapshot.d_keys.shape[0]))
        prev_phase = eng.dispatches.phase
        eng.dispatches.phase = "query"
        try:
            with enable_x64():
                if key not in eng._fns:
                    eng._register_fn(key, jax.jit(jax.vmap(
                        partial(_bgp_one, plan.probes, plan.var_order,
                                self.width),
                        in_axes=(None, None, None, None, 0),
                    )))
                out, valid, overflow = eng._fns[key](
                    snapshot.d_triples, snapshot.d_keys,
                    snapshot.d_triples_pos, snapshot.d_keys_pos,
                    jnp.asarray(consts),
                )
        finally:
            eng.dispatches.phase = prev_phase
        out = np.asarray(out)
        valid = np.asarray(valid)
        overflow = np.asarray(overflow)
        col_of = {cv: k for k, cv in enumerate(plan.var_order)}
        for row, i in enumerate(idxs):
            if overflow[row]:
                results[i] = evaluate_at(queries[i], snapshot, dic)
                self.stats["overflow"] += 1
                continue
            qn, varmap = prepared[i]
            sel = np.flatnonzero(valid[row])
            cols = {
                v: out[row, col_of[cv]][sel].astype(np.int32)
                for v, cv in varmap.items()
            }
            sol = _Solutions(Bindings(cols, int(sel.shape[0])))
            results[i] = (
                _finish(queries[i], qn, sol, snapshot.rho, dic),
                snapshot.epoch,
            )
            self.stats["batched"] += 1
        self.stats["groups"] += 1


# ---------------------------------------------------------------------------
# trace-audit inventory (repro.analysis)
# ---------------------------------------------------------------------------

# representative shapes covering the serving workload's query kinds
# (repro.data.generator): single-predicate scan, object-join pair, and
# bound-object lookup — between them they exercise both key orders, free-var
# binding, bound-var post-filters and non-prefix constants.
_AUDIT_SIGS = (
    ((("v", 0), "c", ("v", 1)),),
    ((("v", 0), "c", ("v", 1)), (("v", 2), "c", ("v", 1))),
    ((("v", 0), "c", "c"),),
)


@register_auditable("bgp")
def _audit_bgp(engine, state):
    # traced at the probe arena's geometry: "arena-length" thresholds apply
    # to the snapshot views exactly as to the live arena they were gathered
    # from.  searchsorted's default scan method binds no sort primitive, so
    # the matcher passes NoArenaSort *without* an exemption — the one
    # publication argsort lives in the "snapshot" family, off this path.
    n = int(state.spo.shape[0])
    tri = jax.ShapeDtypeStruct((n, 3), jnp.int32)
    keys = jax.ShapeDtypeStruct((n,), jnp.int64)
    for si, sig in enumerate(_AUDIT_SIGS):
        plan = build_plan(sig)
        fn = partial(_bgp_one, plan.probes, plan.var_order, 256)
        jx = jax.make_jaxpr(fn)(
            tri, keys, tri, keys,
            jax.ShapeDtypeStruct((max(plan.n_consts, 1),), jnp.int32),
        )
        yield f"bgp:shape{si}", jx
