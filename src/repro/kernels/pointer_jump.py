"""Pallas TPU kernel: one pointer-doubling step ``out[i] = table[idx[i]]``.

The union-find compression loop (DESIGN.md §2) is ``rep = rep[rep]`` iterated
O(log depth) times.  On TPU there is no scalar gather from HBM worth its DMA
cost, so the gather is reformulated as **one-hot matmul over table tiles**:
for each VMEM-resident tile ``table[t0:t0+T]``, rows whose index falls inside
the tile contribute ``onehot(idx - t0) @ tile`` on the MXU; accumulating over
tiles yields the full gather.  Values are resource IDs < 2^21, which are exact
in float32, so the matmul is lossless.

Grid: ``(n_index_blocks, n_table_tiles)`` — the tile dimension iterates
fastest, so output accumulation is safe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, table_ref, out_ref, *, tile: int):
    t = pl.program_id(1)
    idx = idx_ref[...]  # (B, 1) int32
    table = table_ref[...]  # (T, 1) int32
    b = idx.shape[0]
    rel = idx[:, 0] - t * tile  # (B,)
    in_tile = (rel >= 0) & (rel < tile)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, tile), 1)
    onehot = jnp.where(in_tile[:, None], (rel[:, None] == iota), False)
    vals = jnp.dot(
        onehot.astype(jnp.float32),
        table.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # (B, 1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += vals.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def pointer_jump(
    idx: jnp.ndarray,
    table: jnp.ndarray,
    *,
    block: int = 512,
    tile: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """table[idx] for int32 1-D ``idx`` and ``table`` (padded to block/tile)."""
    n = idx.shape[0]
    v = table.shape[0]
    n_pad = -n % block
    v_pad = -v % tile
    idx_p = jnp.pad(idx, (0, n_pad)).reshape(-1, 1)
    table_p = jnp.pad(table, (0, v_pad)).reshape(-1, 1)
    grid = (idx_p.shape[0] // block, table_p.shape[0] // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((idx_p.shape[0], 1), idx.dtype),
        interpret=interpret,
    )(idx_p, table_p)
    return out[:n, 0]
