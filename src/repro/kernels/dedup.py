"""Sort-free stable dedup order via a Pallas counting-rank kernel.

The engine's last per-round delta-width sorts (candidate-stream dedup in
``process_candidates`` step 7, binding-table grouping in ``_expand_join``)
only need the *stable ascending permutation* of a packed int64 key buffer —
nothing downstream wants a sorted array per se, only where each key would
land.  That rank is a counting problem:

    rank[i] = #{j : key[j] < key[i]} + #{j < i : key[j] == key[i]}

which tiles exactly like :mod:`repro.kernels.bsearch`'s counting kernel: a
(query-block x key-tile) grid accumulating per-query counts across key
tiles, with the int64 keys split into (hi, lo) int32 halves so the kernel
never touches a 64-bit lane (hi compares signed — packed keys are
non-negative — and lo compares unsigned).  The split uses
``lax.bitcast_convert_type``, a bit-level reinterpretation, NOT a narrowing
value conversion — the distinction DtypeSafety enforces.

Scattering ``iota`` through the rank then yields the permutation itself:

    order[rank[i]] = i      (== jnp.argsort(keys, stable=True))

O(n^2/p) work instead of O(n log n), with zero sort primitives — the right
trade for the short padded delta buffers of steady-state maintenance where
the XLA sort's dispatch/fusion overhead dominates, and the last piece the
fused round loop needs to lint clean under a no-sort budget.  Opt-in via
``JaxEngine(use_kernel=True)``; invalid slots ride along as KEY_MAX rows
and end up stably last, exactly as under the argsort they replace.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank_kernel(qhi_ref, qlo_ref, khi_ref, klo_ref, rank_ref, *, block, tile):
    i = pl.program_id(0)
    t = pl.program_id(1)
    qhi = qhi_ref[...]  # (block, 1) int32: high halves, signed compare
    khi = khi_ref[...]  # (tile, 1)
    # low halves compare UNSIGNED: reinterpret the int32 bits as uint32
    qlo = qlo_ref[...].astype(jnp.uint32)
    klo = klo_ref[...].astype(jnp.uint32)
    # global element indices tie-break equal keys by position (stability)
    q_idx = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    k_idx = t * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    k_lt_q = (khi[None, :, 0] < qhi[:, :1]) | (
        (khi[None, :, 0] == qhi[:, :1]) & (klo[None, :, 0] < qlo[:, :1])
    )
    k_eq_q = (khi[None, :, 0] == qhi[:, :1]) & (klo[None, :, 0] == qlo[:, :1])
    counts = k_lt_q | (k_eq_q & (k_idx[None, :, 0] < q_idx[:, :1]))

    @pl.when(t == 0)
    def _init():
        rank_ref[...] = jnp.zeros_like(rank_ref)

    rank_ref[...] += jnp.sum(counts, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def _rank_call(qhi, qlo, khi, klo, *, block, tile, interpret):
    n_q, n_k = qhi.shape[0], khi.shape[0]
    grid = (n_q // block, n_k // tile)
    return pl.pallas_call(
        functools.partial(_rank_kernel, block=block, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, 1), jnp.int32),
        interpret=interpret,
    )(qhi, qlo, khi, klo)


def _split_halves(keys):
    """(n,) int64 -> ((n,1) hi int32, (n,1) lo int32) via bitcast.

    ``bitcast_convert_type`` to a narrower type adds a minor dimension of
    size 2 ordered low-half-first; no value conversion happens, so packed
    keys keep their 63 bits across the split.
    """
    parts = jax.lax.bitcast_convert_type(keys, jnp.int32)  # (n, 2)
    return parts[:, 1:2], parts[:, 0:1]


def dedup_order(keys, *, block: int = 128, tile: int = 128, interpret=None):
    """Stable ascending permutation of ``keys`` ((n,) int64, non-negative).

    ``order = dedup_order(k)`` satisfies ``k[order] == jnp.sort(k)`` with
    ties kept in input order — a drop-in for
    ``jnp.argsort(keys, stable=True)`` built from counting + one
    delta-width scatter, no sort primitive.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = keys.shape[0]
    kmax = jnp.asarray((1 << 63) - 1, keys.dtype)
    q_pad = -n % block
    k_pad = -n % tile
    q = jnp.concatenate([keys, jnp.full((q_pad,), kmax)]) if q_pad else keys
    k = jnp.concatenate([keys, jnp.full((k_pad,), kmax)]) if k_pad else keys
    qhi, qlo = _split_halves(q)
    khi, klo = _split_halves(k)
    # key-side padding never perturbs real ranks: a pad is >= every key and
    # its tie-break index >= n, so it counts into no query slot below n
    rank = _rank_call(
        qhi, qlo, khi, klo, block=block, tile=tile, interpret=interpret
    )[:n, 0]
    iota = jnp.arange(n, dtype=jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[rank].set(iota)
