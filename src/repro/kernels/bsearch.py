"""Pallas TPU kernel: batched sorted-set search bounds by *counting*.

The engine's merge joins need (lower, upper) bounds of query keys in a sorted
key column.  Classic binary search needs log(N) dependent gathers — hostile
to the VPU.  The TPU-idiomatic formulation: for sorted keys,

    lower[q] = #{k : k < q},     upper[q] = #{k : k <= q},

which is a tiled compare-and-reduce — pure VPU work, trivially blocked, and
accumulation-safe over key tiles.  Keys are the engine's packed int64 values
split into (hi, lo) int32 pairs compared lexicographically (lo unsigned).

Grid: (n_query_blocks, n_key_tiles); key tiles iterate fastest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(qhi_ref, qlo_ref, khi_ref, klo_ref, lo_ref, hi_ref):
    t = pl.program_id(1)
    qhi = qhi_ref[...]  # (B, 1) int32
    qlo = qlo_ref[...].astype(jnp.uint32)
    khi = khi_ref[...]  # (T, 1) int32
    klo = klo_ref[...].astype(jnp.uint32)

    # lexicographic (hi, lo-unsigned) compare, broadcast (B, T)
    k_lt_q = (khi[None, :, 0] < qhi[:, :1]) | (
        (khi[None, :, 0] == qhi[:, :1]) & (klo[None, :, 0] < qlo[:, :1])
    )
    k_le_q = (khi[None, :, 0] < qhi[:, :1]) | (
        (khi[None, :, 0] == qhi[:, :1]) & (klo[None, :, 0] <= qlo[:, :1])
    )

    @pl.when(t == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    lo_ref[...] += jnp.sum(k_lt_q, axis=1, keepdims=True).astype(jnp.int32)
    hi_ref[...] += jnp.sum(k_le_q, axis=1, keepdims=True).astype(jnp.int32)


def search_bounds(
    queries,
    keys,
    *,
    block: int = 256,
    tile: int = 1024,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(lower, upper) positions of int64 ``queries`` in sorted int64 ``keys``.

    The int64 -> (hi, lo) int32 split happens on the host with numpy so the
    kernel never needs the x64 flag.  Padding keys must sort above every real
    key: INT64_MAX, which the engine reserves as a sentinel.
    """
    import numpy as np

    queries = np.asarray(queries, np.int64)
    keys = np.asarray(keys, np.int64)
    n, v = queries.shape[0], keys.shape[0]
    n_pad = -n % block
    v_pad = -v % tile
    q = np.pad(queries, (0, n_pad))
    k = np.pad(keys, (0, v_pad), constant_values=(1 << 63) - 1)

    def split(x):
        hi = (x >> 32).astype(np.int32).reshape(-1, 1)
        lo = (x & np.int64((1 << 32) - 1)).astype(np.uint32)
        return jnp.asarray(hi), jnp.asarray(lo.astype(np.int32).reshape(-1, 1))

    qhi, qlo = split(q)
    khi, klo = split(k)
    lo, hi = _search_bounds_call(qhi, qlo, khi, klo, block=block, tile=tile, interpret=interpret)
    return lo[:n, 0], hi[:n, 0]


def prefix_range_bounds(
    prefix_cols,
    keys,
    *,
    block: int = 256,
    tile: int = 1024,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(start, end) index ranges of (s, p, o)-prefix queries in sorted keys.

    The kernel form of the persistent-index range probe the engine's
    head-bound joins issue per binding (``_expand_join_index`` /
    ``eval_plan_rederive``): an atom whose fixed positions form a length-k
    prefix of the packed (s, p, o) key order matches exactly the keys in
    ``[pack(prefix, 0...), pack(prefix, max...)]``, so its range is one
    lower bound of the low key and one upper bound of the high key — both
    produced by the same counting kernel in a single fused call (low and
    high queries concatenated).

    ``prefix_cols`` is an (n, k) int array of the leading fixed positions,
    1 <= k <= 3, values below ``2**21`` (the engine's ID width).  Returns
    int32 arrays with ``start[i]:end[i]`` the half-open match range of
    query ``i``.
    """
    import numpy as np

    pc = np.asarray(prefix_cols, np.int64)
    n, k = pc.shape
    if not 1 <= k <= 3:
        raise ValueError(f"prefix length must be 1..3, got {k}")
    maxid = np.int64((1 << 21) - 1)
    lo = np.zeros(n, np.int64)
    hi = np.zeros(n, np.int64)
    for j in range(3):
        lo = (lo << 21) | (pc[:, j] if j < k else 0)
        hi = (hi << 21) | (pc[:, j] if j < k else maxid)
    lower, upper = search_bounds(
        np.concatenate([lo, hi]), keys, block=block, tile=tile,
        interpret=interpret,
    )
    return lower[:n], upper[n:]


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def _search_bounds_call(qhi, qlo, khi, klo, *, block, tile, interpret):
    grid = (qhi.shape[0] // block, khi.shape[0] // tile)
    lo, hi = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qhi.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((qhi.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(qhi, qlo, khi, klo)
    # padded keys sort above all queries, so counts need no correction;
    # padded queries produce garbage rows that the caller slices away.
    return lo, hi
