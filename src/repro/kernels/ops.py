"""Jit'd public wrappers for the Pallas kernels.

On the CPU container, kernels run in ``interpret=True`` mode (the kernel body
executes as traced JAX ops — bit-identical semantics, no Mosaic lowering); on
a real TPU backend ``interpret=False`` compiles to Mosaic.  ``INTERPRET``
auto-detects.
"""

from __future__ import annotations

import jax

from .bsearch import (
    prefix_range_bounds as _prefix_range_bounds,
    search_bounds as _search_bounds,
)
from .dedup import dedup_order as _dedup_order
from .embedding_bag import embedding_bag as _embedding_bag
from .flash_attention import flash_attention_bhsd as _flash_attention_bhsd
from .fm_interact import fm_interact as _fm_interact
from .pointer_jump import pointer_jump as _pointer_jump
from .rewrite_triples import rewrite_triples as _rewrite_triples
from .segment_sum import segment_sum as _segment_sum

INTERPRET = jax.default_backend() != "tpu"


def pointer_jump(idx, table, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _pointer_jump(idx, table, **kw)


def rewrite_triples(spo, rho, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _rewrite_triples(spo, rho, **kw)


def search_bounds(queries, keys, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _search_bounds(queries, keys, **kw)


def prefix_range_bounds(prefix_cols, keys, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _prefix_range_bounds(prefix_cols, keys, **kw)


def dedup_order(keys, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _dedup_order(keys, **kw)


def embedding_bag(ids, table, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _embedding_bag(ids, table, **kw)


def fm_interact(x, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _fm_interact(x, **kw)


def segment_sum(x, seg, n_segments, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _segment_sum(x, seg, n_segments, **kw)


def flash_attention(q, k, v, causal=True, q_offset=0, **kw):
    """q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,D); GQA flash attention."""
    kw.setdefault("interpret", INTERPRET)
    out = _flash_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        q_offset,
        causal=causal,
        **kw,
    )
    return out.transpose(0, 2, 1, 3)
