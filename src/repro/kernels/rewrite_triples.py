"""Pallas TPU kernel: fused triple rewrite ``out = rho[spo]`` + changed mask.

The bulk Algorithm-3 sweep (DESIGN.md §2): every triple's three positions are
mapped through the representative table and a per-row 'outdated' flag is
produced in the same pass.  Same one-hot-matmul gather as
:mod:`repro.kernels.pointer_jump`, with the (B,3) block flattened to (3B,1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(spo_ref, rho_ref, out_ref, changed_ref, *, tile: int):
    t = pl.program_id(1)
    spo = spo_ref[...]  # (B, 3) int32
    rho = rho_ref[...]  # (T, 1) int32
    b = spo.shape[0]
    flat = spo.reshape(b * 3)
    rel = flat - t * tile
    in_tile = (rel >= 0) & (rel < tile)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b * 3, tile), 1)
    onehot = jnp.where(in_tile[:, None], rel[:, None] == iota, False)
    vals = jnp.dot(
        onehot.astype(jnp.float32),
        rho.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32).reshape(b, 3)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        changed_ref[...] = jnp.zeros_like(changed_ref)

    out_ref[...] += vals
    diff = in_tile.reshape(b, 3) & (vals != spo)
    changed_ref[...] |= jnp.any(diff, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def rewrite_triples(
    spo: jnp.ndarray,
    rho: jnp.ndarray,
    *,
    block: int = 256,
    tile: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (rho[spo], changed) for (n,3) int32 triples."""
    n = spo.shape[0]
    v = rho.shape[0]
    n_pad = -n % block
    v_pad = -v % tile
    spo_p = jnp.pad(spo, ((0, n_pad), (0, 0)))
    rho_p = jnp.pad(rho, (0, v_pad)).reshape(-1, 1)
    grid = (spo_p.shape[0] // block, rho_p.shape[0] // tile)
    out, changed = pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 3), lambda i, t: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i, t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 3), lambda i, t: (i, 0)),
            pl.BlockSpec((block, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((spo_p.shape[0], 3), jnp.int32),
            jax.ShapeDtypeStruct((spo_p.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(spo_p, rho_p)
    return out[:n], changed[:n, 0].astype(bool)


def rewrite_owner(
    spo: jnp.ndarray, rho: jnp.ndarray, n_shards: int, **kw
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ``(rho[spo], owner)`` where owner = subject representative mod
    the shard count — the routing key of the engine's owner-routed delta
    exchange.  Used by the incremental delete path to owner-sort tombstone
    seed queries before they are shipped to the mesh."""
    out, _changed = rewrite_triples(spo, rho, **kw)
    return out, out[:, 0] % jnp.int32(n_shards)
