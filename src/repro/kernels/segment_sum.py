"""Pallas TPU kernel: segment-sum (the GNN message-passing scatter).

``out[s] = sum_{i : seg[i] == s} x[i]`` — the core aggregation of every
SpMM-regime GNN (GCN/GatedGCN/PNA message reduce) and of the EmbeddingBag
gradient.  TPU-native formulation: transpose-one-hot matmul per (segment
tile × input block): ``onehot(seg - s0)^T @ x`` on the MXU, accumulated over
input blocks.

Grid: (n_segment_tiles, n_input_blocks); input blocks iterate fastest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(seg_ref, x_ref, out_ref, *, stile: int):
    s = pl.program_id(0)
    i = pl.program_id(1)
    seg = seg_ref[...]  # (B, 1) int32
    x = x_ref[...]  # (B, K)
    b = seg.shape[0]
    rel = seg[:, 0] - s * stile
    in_tile = (rel >= 0) & (rel < stile)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b, stile), 1)
    onehot = jnp.where(in_tile[:, None], rel[:, None] == iota, False)
    contrib = jnp.dot(
        onehot.astype(x.dtype).T, x, preferred_element_type=jnp.float32
    )  # (S_t, K)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_segments", "block", "stile", "interpret"))
def segment_sum(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    n_segments: int,
    *,
    block: int = 512,
    stile: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """(N, K) values + (N,) int32 segment ids -> (n_segments, K) sums."""
    n, k = x.shape
    n_pad = -n % block
    s_pad = -n_segments % stile
    x_p = jnp.pad(x, ((0, n_pad), (0, 0)))
    seg_p = jnp.pad(seg, (0, n_pad), constant_values=n_segments + s_pad).reshape(-1, 1)
    n_seg_p = n_segments + s_pad
    grid = (n_seg_p // stile, x_p.shape[0] // block)
    out = pl.pallas_call(
        functools.partial(_kernel, stile=stile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 1), lambda s, i: (i, 0)),
            pl.BlockSpec((block, k), lambda s, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((stile, k), lambda s, i: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((n_seg_p, k), jnp.float32),
        interpret=interpret,
    )(seg_p, x_p)
    return out[:n_segments].astype(x.dtype)
