"""Pallas TPU kernel: EmbeddingBag (gather + per-sample reduce) for recsys.

JAX has no native EmbeddingBag; the assignment mandates building it.  For a
batch of per-field categorical IDs ``ids (B, F)`` and a table ``(V, K)``, the
bag output is ``out[b] = sum_f table[ids[b, f]]``.  TPU-native formulation:
tile the table over VMEM; for each tile, ``onehot(ids - t0) @ tile`` on the
MXU contributes the rows that live in the tile; sum over the field axis
happens in the same pass (fused reduce).

Grid: (n_batch_blocks, n_table_tiles); table tiles iterate fastest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, table_ref, out_ref, *, tile: int):
    t = pl.program_id(1)
    ids = ids_ref[...]  # (B, F) int32
    table = table_ref[...]  # (T, K)
    b, f = ids.shape
    rel = ids.reshape(b * f) - t * tile
    in_tile = (rel >= 0) & (rel < tile)
    iota = jax.lax.broadcasted_iota(jnp.int32, (b * f, tile), 1)
    onehot = jnp.where(in_tile[:, None], rel[:, None] == iota, False)
    gathered = jnp.dot(
        onehot.astype(table.dtype), table, preferred_element_type=jnp.float32
    )  # (B*F, K)
    bag = gathered.reshape(b, f, -1).sum(axis=1)  # fused field reduce

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += bag.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile", "interpret"))
def embedding_bag(
    ids: jnp.ndarray,
    table: jnp.ndarray,
    *,
    block: int = 128,
    tile: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """out (B, K) = sum_f table[ids[:, f]] for int32 ids (B, F)."""
    b, f = ids.shape
    v, k = table.shape
    b_pad = -b % block
    v_pad = -v % tile
    ids_p = jnp.pad(ids, ((0, b_pad), (0, 0)), constant_values=v + v_pad)  # off-table
    table_p = jnp.pad(table, ((0, v_pad), (0, 0)))
    grid = (ids_p.shape[0] // block, table_p.shape[0] // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, f), lambda i, t: (i, 0)),
            pl.BlockSpec((tile, k), lambda i, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((block, k), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ids_p.shape[0], k), jnp.float32),
        interpret=interpret,
    )(ids_p, table_p)
    return out[:b].astype(table.dtype)
