"""Pure-jnp oracles for every Pallas kernel (the ref.py contract)."""

from __future__ import annotations

import jax.numpy as jnp


def pointer_jump_ref(idx: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return table[idx]


def rewrite_triples_ref(spo: jnp.ndarray, rho: jnp.ndarray):
    out = rho[spo]
    changed = jnp.any(out != spo, axis=1)
    return out, changed


def search_bounds_ref(queries, keys):
    # numpy (not jnp): int64 keys must survive without the x64 flag
    import numpy as np

    queries = np.asarray(queries, np.int64)
    keys = np.asarray(keys, np.int64)
    lo = np.searchsorted(keys, queries, side="left")
    hi = np.searchsorted(keys, queries, side="right")
    return lo.astype(np.int32), hi.astype(np.int32)


def prefix_range_bounds_ref(prefix_cols, keys):
    # numpy (not jnp): int64 packed keys must survive without the x64 flag
    import numpy as np

    pc = np.asarray(prefix_cols, np.int64)
    keys = np.asarray(keys, np.int64)
    maxid = np.int64((1 << 21) - 1)
    lo = np.zeros(pc.shape[0], np.int64)
    hi = np.zeros(pc.shape[0], np.int64)
    for j in range(3):
        lo = (lo << 21) | (pc[:, j] if j < pc.shape[1] else 0)
        hi = (hi << 21) | (pc[:, j] if j < pc.shape[1] else maxid)
    start = np.searchsorted(keys, lo, side="left")
    end = np.searchsorted(keys, hi, side="right")
    return start.astype(np.int32), end.astype(np.int32)


def dedup_order_ref(keys):
    # numpy (not jnp): int64 packed keys must survive without the x64 flag
    import numpy as np

    return np.argsort(np.asarray(keys, np.int64), kind="stable").astype(
        np.int32
    )


def embedding_bag_ref(ids: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return table[ids].sum(axis=1)


def fm_interact_ref(x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    s = xf.sum(axis=1)
    sq = (xf * xf).sum(axis=1)
    return (0.5 * (s * s - sq).sum(axis=1)).astype(x.dtype)


def segment_sum_ref(x: jnp.ndarray, seg: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    import jax

    return jax.ops.segment_sum(x, seg, num_segments=n_segments)
