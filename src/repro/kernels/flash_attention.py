"""Flash attention (fwd) as a Pallas TPU kernel — GQA, causal, KV-cache.

WHY (roofline): the XLA chunked-attention path materialises the (S, T)
score/prob blocks in HBM every chunk — the dry-run shows this traffic
DOMINATES the memory term of every LM train/prefill cell (e.g.
starcoder2:train_4k memory 14.3s vs compute 3.7s).  This kernel keeps the
online-softmax state (m, l, acc) in VMEM scratch across KV-block grid steps,
so per (q-block, kv-block) step HBM traffic is just the q/k/v tile loads +
one output tile store — the classic flash-attention restructuring, here
tiled for the MXU (128-aligned blocks) and the HBM->VMEM hierarchy.

Grid: (B, H, S/bq, T/bk), kv innermost (``arbitrary`` semantics) so the
scratch carries across kv steps of one (b, h, q-block) cell.  GQA maps query
head h to kv head h // (H // KV) in the k/v index_maps — no KV duplication
in HBM.  Causality is enforced by masking and (on TPU) the ``pl.when`` skip
of fully-masked blocks; ``q_offset`` supports decode (queries at cache
positions >= q_offset).

VMEM budget per step (defaults bq=bk=128, D=128, f32 scratch):
  q/k/v tiles 3*128*128*2B = 96 KiB, acc 128*128*4B = 64 KiB, m/l 1 KiB
  — comfortably inside the ~16 MiB/core VMEM; D up to 256 still fits 4x.

Validated in interpret mode against the pure-jnp oracle
(tests/test_kernels.py::test_flash_attention_*); the jittable wrapper with
padding/GQA plumbing is ``ops.flash_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, qoff_ref, out_ref, m_ref, l_ref, acc_ref,
    *, causal: bool, t_actual: int, block_q: int, block_k: int, scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < t_actual
    if causal:
        q_pos = (
            qoff_ref[0]
            + iq * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        )
        mask = mask & (q_pos >= k_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (bq, bk)
    l_new = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_k - 1)
    def _finalise():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, KV, T, D)
    v: jnp.ndarray,  # (B, KV, T, D)
    q_offset: jnp.ndarray,  # () int32
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    g = h // kv
    bq = min(block_q, s)
    bk = min(block_k, t)
    s_pad = (s + bq - 1) // bq * bq
    t_pad = (t + bk - 1) // bk * bk
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))

    grid = (b, h, s_pad // bq, t_pad // bk)
    kernel = functools.partial(
        _flash_kernel,
        causal=causal, t_actual=t, block_q=bq, block_k=bk,
        scale=1.0 / (d**0.5),
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1,), lambda bi, hi, qi, ki: (0,)),  # q_offset scalar
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, jnp.asarray(q_offset, jnp.int32).reshape(1))
    return out[:, :, :s, :]
