"""Pallas TPU kernel: fused FM pairwise interaction (sum-square trick).

FM (Rendle, ICDM'10): sum_{i<j} <v_i, v_j> x_i x_j computed in O(F*K) as
``0.5 * sum_k ((sum_f x)^2 - sum_f x^2)``.  One fused VPU pass per batch
block: both reductions and the final combine never leave VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, out_ref, *, f: int, k: int):
    x = x_ref[...]  # (B, F*K)
    b = x.shape[0]
    xf = x.reshape(b, f, k).astype(jnp.float32)
    s = xf.sum(axis=1)  # (B, K)
    sq = (xf * xf).sum(axis=1)  # (B, K)
    out_ref[...] = (0.5 * (s * s - sq).sum(axis=1, keepdims=True)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fm_interact(
    x: jnp.ndarray,
    *,
    block: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """x (B, F, K) field embeddings (already scaled by feature values) ->
    (B,) second-order FM interaction term."""
    b, f, k = x.shape
    b_pad = -b % block
    x_p = jnp.pad(x.reshape(b, f * k), ((0, b_pad), (0, 0)))
    grid = (x_p.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_kernel, f=f, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block, f * k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_p.shape[0], 1), x.dtype),
        interpret=interpret,
    )(x_p)
    return out[:b, 0]
