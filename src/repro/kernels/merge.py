"""Rank-merge of two sorted key/value columns — the index-maintenance op.

The engine's persistent sorted arena index
(:class:`repro.core.engine_jax.EngineState` ``sorted_keys``/``sort_perm``)
is updated on insertion by merging a small, already-sorted fresh delta into
the big sorted index.  A serial two-pointer merge is O(A+B) but sequential —
hostile to the VPU; the data-parallel formulation computes each element's
final position directly as *its own index plus its rank in the other
column*:

    pos_a[i] = i + #{j : b[j] <  a[i]}      (ties: a-side first)
    pos_b[j] = j + #{i : a[i] <= b[j]}

which is two ``searchsorted`` calls and one scatter — O((A+B) log) compares,
no sort.  The left/right tie-break makes the positions exactly the (stable)
merge permutation: collision-free even with duplicate keys.

Padding uses KEY_MAX sentinels, which sort above every real key, so
truncating the merged result back to the index capacity only ever drops
padding (the engine guarantees live rows <= capacity; overflow is detected
upstream and raises the capacity retry).

The counting formulation of the companion Pallas kernel
(:mod:`repro.kernels.bsearch`, ``search_bounds``) computes the same ranks as
tiled compare-and-reduce on TPU; this module stays pure jnp so it can run
inside ``shard_map`` on any backend and under the engine's x64 scope.
"""

from __future__ import annotations

import jax.numpy as jnp


def merge_ranks(a_keys: jnp.ndarray, b_keys: jnp.ndarray):
    """Positions of each element of two sorted columns in their merge.

    Ties place all ``a`` elements before the equal ``b`` elements (the
    stable order for merging a fresh delta *behind* the existing index is
    irrelevant here because the engine never merges duplicate live keys;
    the convention just guarantees distinct positions).
    """
    pos_a = jnp.arange(a_keys.shape[0]) + jnp.searchsorted(
        b_keys, a_keys, side="left"
    )
    pos_b = jnp.arange(b_keys.shape[0]) + jnp.searchsorted(
        a_keys, b_keys, side="right"
    )
    return pos_a, pos_b


def merge_sorted(
    a_keys: jnp.ndarray,
    a_vals: jnp.ndarray,
    b_keys: jnp.ndarray,
    b_vals: jnp.ndarray,
    out_len: int | None = None,
):
    """Merge sorted ``(keys, vals)`` columns, truncated to ``out_len`` rows.

    Both inputs must be individually sorted ascending.  Returns the first
    ``out_len`` (default: ``len(a)``) rows of the merged order — safe when
    everything past ``out_len`` is known to be sentinel padding.

    Gather formulation (cheaper than scattering on CPU backends when ``b``
    is the small side): output position ``p`` holds the ``b`` element whose
    merge position ``pos_b`` equals ``p``, else the ``a`` element at index
    ``p - #{b placed before p}`` — both found by binary search over the
    monotone ``pos_b``.
    """
    A, B = a_keys.shape[0], b_keys.shape[0]
    out_len = A if out_len is None else out_len
    if B == 0:
        return a_keys[:out_len], a_vals[:out_len]
    pos_b = jnp.arange(B) + jnp.searchsorted(a_keys, b_keys, side="right")
    p = jnp.arange(out_len)
    ib = jnp.searchsorted(pos_b, p, side="left")
    from_b = pos_b[jnp.clip(ib, 0, B - 1)] == p
    ja = jnp.clip(p - ib, 0, A - 1)
    jb = jnp.clip(ib, 0, B - 1)
    keys = jnp.where(from_b, b_keys[jb], a_keys[ja])
    vals = jnp.where(from_b, b_vals[jb], a_vals[ja])
    return keys, vals
