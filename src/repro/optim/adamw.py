"""AdamW with global-norm clipping and ZeRO-1-style state sharding.

Optimizer moments are f32 and their shardings extend the parameter sharding
by splitting the largest replicated-or-model axis over ``data`` where the
shape allows — this is what makes the 235B MoE optimizer state fit 16 GB/chip
(DESIGN.md §6).  Update math is standard AdamW on f32 upcasts of bf16 params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(
    params,
    grads,
    state,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_norm: float = 1.0,
    mom_shardings=None,
    param_shardings=None,
):
    """AdamW step.  With ``mom_shardings`` given (ZeRO-1), each gradient is
    first CONSTRAINED to the moment sharding — GSPMD then reduce-scatters the
    grads over data, runs the update shard-locally, and all-gathers only the
    bf16 params back (constrained to ``param_shardings``).  Without the
    constraints the update math runs at param sharding, transiently
    materialising full f32 moments (53 GiB/device on the 235B config).

    The grad constraint is applied BEFORE the global-norm clip: sharding
    propagates backwards into the scan-over-layers gradient accumulator, so
    stacked grads are born sharded (ZeRO-2-style; ~26 GiB/device of
    transient bf16 expert grads otherwise on 235B), and the clip reductions
    run on shards."""
    if mom_shardings is not None:
        flat_g_, gdef_ = jax.tree_util.tree_flatten(grads)
        flat_s_ = jax.tree_util.tree_leaves(mom_shardings)
        flat_g_ = [
            jax.lax.with_sharding_constraint(g, s)
            for g, s in zip(flat_g_, flat_s_)
        ]
        grads = jax.tree_util.tree_unflatten(gdef_, flat_g_)
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, g, mu, nu, ms=None, ps=None):
        g = g.astype(jnp.float32)
        if ms is not None:
            g = jax.lax.with_sharding_constraint(g, ms)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        pf = p.astype(jnp.float32)
        if ms is not None:
            pf = jax.lax.with_sharding_constraint(pf, ms)
        pf = pf - lr * (u + weight_decay * pf)
        new_p = pf.astype(p.dtype)
        if ps is not None:
            new_p = jax.lax.with_sharding_constraint(new_p, ps)
        return new_p, mu, nu

    # explicit flatten: param pytrees may contain structural tuples (GNN
    # mlp layers are (w, b) pairs), so per-leaf tuple returns cannot be
    # disambiguated by tree.map(is_leaf=tuple)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    if mom_shardings is not None:
        flat_ms = jax.tree_util.tree_leaves(mom_shardings)
        flat_ps = jax.tree_util.tree_leaves(param_shardings)
    else:
        flat_ms = flat_ps = [None] * len(flat_p)
    out = [
        upd(p, g, mu, nu, ms, ps)
        for p, g, mu, nu, ms, ps in zip(
            flat_p, flat_g, flat_mu, flat_nu, flat_ms, flat_ps
        )
    ]
    unflat = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in out])
    return unflat(0), {"mu": unflat(1), "nu": unflat(2), "step": step}, gnorm


def _zero1_sharding(ns: NamedSharding, shape, mesh, dp: tuple[str, ...]):
    """Extend a param sharding with data-axis sharding over a free dimension
    (ZeRO-1): pick the first dimension that is unsharded and divisible."""
    if not dp:
        return ns
    used = {a for s in ns.spec for a in ((s,) if isinstance(s, str) else (s or ()))}
    if used & set(dp):
        return ns  # already dp-sharded (e.g. FSDP params)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % dp_size == 0 and dim >= dp_size:
            spec[i] = dp if len(dp) > 1 else dp[0]
            return NamedSharding(mesh, P(*spec))
    return ns  # too small to shard further — stays as the param sharding


def opt_state_shardings(param_shardings, param_shapes, mesh, dp=("pod", "data")):
    """Shardings pytree for adamw state given the param shardings."""
    dp = tuple(a for a in dp if a in mesh.axis_names)
    mom = jax.tree.map(
        lambda ns, sh: _zero1_sharding(ns, sh.shape, mesh, dp),
        param_shardings,
        param_shapes,
    )
    return {"mu": mom, "nu": mom, "step": NamedSharding(mesh, P())}
