"""int8 error-feedback gradient compression for cross-pod data parallelism.

The cross-pod (DCN) all-reduce is the slowest exchange at 1000+ node scale;
compressing gradients to int8 with per-tensor scales cuts its bytes 4x vs
f32 (2x vs bf16).  Plain quantisation biases the update, so we keep the
classic error-feedback residual (Seide et al. '14; Karimireddy et al. '19):

    q_t  = Q(g_t + e_t)          # quantise gradient + carried residual
    e_t1 = (g_t + e_t) - D(q_t)  # residual of what the wire lost

which preserves convergence — the residual is replayed on later steps
(property-tested in tests/test_compression.py).

``compressed_grad_exchange`` must run in a named-axis context (inside the
``shard_map`` over the pod axis that the train loop builds — see
train/loop.py); ``quantize_int8``/``compress_with_feedback`` are pure and
usable anywhere.  The intra-pod reduction stays uncompressed (ICI is fast);
only the pod-axis exchange is quantised.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(g: jnp.ndarray, e: jnp.ndarray):
    """One tensor: returns ((int8 payload, f32 scale), new residual)."""
    gf = g.astype(jnp.float32) + e
    q, s = quantize_int8(gf)
    new_e = gf - dequantize_int8(q, s)
    return (q, s), new_e


def compressed_grad_exchange(grads, residuals, axis: str = "pod"):
    """Error-feedback int8 mean-all-reduce over named ``axis``.

    Call inside a shard_map/pmap body where ``axis`` is bound.  The int8
    payload is what crosses the wire (the psum of the dequantised values is
    how XLA sees it; on the DCN the transfer is the int8 tensor + scalar).
    Returns (mean gradients, new residuals).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        (q, s), new_e = compress_with_feedback(g, e)
        total = jax.lax.psum(dequantize_int8(q, s), axis)
        return (total / n).astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(residuals)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [m for m, _ in out])
    new_res = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return mean, new_res


def wire_bytes(params) -> tuple[int, int]:
    """(compressed, f32) bytes per exchange — for the roofline/§Perf log."""
    leaves = jax.tree.leaves(params)
    comp = sum(int(jnp.size(p)) + 4 for p in leaves)  # int8 payload + scale
    full = sum(4 * int(jnp.size(p)) for p in leaves)
    return comp, full
