"""Test datasets: the paper's running example P_ex and clique-generators.

The paper evaluates on Claros / DBpedia / OpenCyc / UniProt / UOBM.  Those
dumps are not available offline, so :mod:`repro.data.generator` synthesises
knowledge graphs with the *characteristics* the paper identifies as driving
the AX/REW gap: the number and size of sameAs cliques, the density of triples
over clique members, and (for the UOBM effect) a symmetric+transitive
property that produces equality-free duplicate derivations.
"""

from __future__ import annotations

import numpy as np

from repro.core.rules import Program, parse_facts, parse_program
from repro.core.terms import Dictionary


def pex() -> tuple[np.ndarray, Program, Dictionary]:
    """P_ex from paper §3: rules (R), (S) and facts (F1)-(F3)."""
    dic = Dictionary()
    program = parse_program(
        [
            "(?x, owl:sameAs, :USA) <- (:Obama, :presidentOf, ?x)",
            "(?x, owl:sameAs, :Obama) <- (?x, :presidentOf, :USA)",
        ],
        dic,
    )
    facts = parse_facts(
        [
            "(:USPresident, :presidentOf, :US)",
            "(:Obama, :presidentOf, :America)",
            "(:Obama, :presidentOf, :US)",
        ],
        dic,
    )
    return facts, program, dic


def pex_rule_rewrite() -> tuple[np.ndarray, Program, Dictionary]:
    """P_ex variant where the representative is NOT the rule constant.

    Facts are interned first so ``:US`` gets a smaller ID than ``:USA``;
    min-ID hooking then makes ``:US`` the representative, and rule (S)
    ``(?x, sameAs, :Obama) <- (?x, :presidentOf, :USA)`` can only fire after
    being rewritten to use ``:US`` — the paper's §3 failure case for systems
    that rewrite facts but not rules ("if we choose :US as the representative
    ... rule (S) will not be applicable").
    """
    dic = Dictionary()
    facts = parse_facts(
        [
            "(:USPresident, :presidentOf, :US)",
            "(:Obama, :presidentOf, :America)",
            "(:Obama, :presidentOf, :US)",
        ],
        dic,
    )
    program = parse_program(
        [
            "(?x, owl:sameAs, :USA) <- (:Obama, :presidentOf, ?x)",
            "(?x, owl:sameAs, :Obama) <- (?x, :presidentOf, :USA)",
        ],
        dic,
    )
    return facts, program, dic


def single_clique(n: int) -> tuple[np.ndarray, Program, Dictionary]:
    """n resources a_1..a_n chained by explicit sameAs facts (one clique).

    Used to validate the paper's §3 closed forms for the AX blowup.
    """
    dic = Dictionary()
    ids = dic.intern_many([f":a{i}" for i in range(n)])
    rows = [(ids[i], dic.intern("owl:sameAs"), ids[i + 1]) for i in range(n - 1)]
    return np.asarray(rows, dtype=np.int32), Program([]), dic


def clique_with_spokes(
    n_clique: int, n_spokes: int
) -> tuple[np.ndarray, Program, Dictionary]:
    """A clique of size n plus triples pointing at one clique member.

    Validates the <s,p,o> copy-expansion claim: each spoke triple must expand
    to n copies, each derived (n + 1 + 1) times under AX.
    """
    dic = Dictionary()
    ids = dic.intern_many([f":c{i}" for i in range(n_clique)])
    sa = dic.intern("owl:sameAs")
    p = dic.intern(":spoke")
    rows = [(ids[i], sa, ids[i + 1]) for i in range(n_clique - 1)]
    for j in range(n_spokes):
        s = dic.intern(f":s{j}")
        rows.append((s, p, ids[0]))
    return np.asarray(rows, dtype=np.int32), Program([]), dic
