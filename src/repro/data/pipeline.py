"""Synthetic data pipelines for all three architecture families.

Deterministic per-step generation (seeded by step index) so a restarted run
resumes with identical batches — part of the fault-tolerance story: the
checkpoint stores only the step counter, not the data state.
"""

from __future__ import annotations

import numpy as np


def lm_batch(step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng(1234 + step)
    tokens = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def random_graph(
    rng: np.random.Generator, n_nodes: int, n_edges: int, d_feat: int, n_classes: int
) -> dict:
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return {
        "x": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "edge_index": np.stack([src, dst]),
        "edge_attr": rng.normal(size=(n_edges, 1)).astype(np.float32),
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
        "train_mask": (rng.random(n_nodes) < 0.5).astype(np.float32),
    }


def molecule_batch(
    rng: np.random.Generator,
    n_graphs: int,
    nodes_per: int,
    edges_per: int,
    n_species: int = 16,
) -> dict:
    """Batched small graphs, flattened with graph_ids (+ triplets for DimeNet)."""
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    offs = np.repeat(np.arange(n_graphs) * nodes_per, edges_per)
    src = rng.integers(0, nodes_per, e).astype(np.int32) + offs.astype(np.int32)
    dst = rng.integers(0, nodes_per, e).astype(np.int32) + offs.astype(np.int32)
    # avoid self loops (distance 0 breaks angular terms)
    dst = np.where(dst == src, (dst + 1 - offs.astype(np.int32)) % nodes_per + offs.astype(np.int32), dst)
    batch = {
        "z": rng.integers(0, n_species, n).astype(np.int32),
        "x": rng.normal(size=(n, 16)).astype(np.float32),
        "pos": rng.normal(size=(n, 3)).astype(np.float32) * 2.0,
        "edge_index": np.stack([src, dst]),
        "edge_attr": rng.normal(size=(e, 1)).astype(np.float32),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32),
        "n_graphs": n_graphs,
        "y": rng.normal(size=(n_graphs,)).astype(np.float32),
    }
    batch["triplets"] = build_triplets(batch["edge_index"], max_triplets=4 * e)
    return batch


def build_triplets(edge_index: np.ndarray, max_triplets: int) -> np.ndarray:
    """(2, T) arrays (edge k->j, edge j->i) for DimeNet, capped + padded.

    For each directed edge e2=(j->i), pair with incoming edges e1=(k->j),
    k != i.  Padding repeats triplet 0 (self-consistent; contributes the same
    value deterministically and is sliced away by the cap in real pipelines).
    """
    src, dst = edge_index
    e = src.shape[0]
    by_dst: dict[int, list[int]] = {}
    for idx in range(e):
        by_dst.setdefault(int(dst[idx]), []).append(idx)
    t_in, t_out = [], []
    for e2 in range(e):
        j = int(src[e2])
        for e1 in by_dst.get(j, ()):
            if int(src[e1]) != int(dst[e2]):
                t_in.append(e1)
                t_out.append(e2)
                if len(t_in) >= max_triplets:
                    break
        if len(t_in) >= max_triplets:
            break
    if not t_in:
        t_in, t_out = [0], [0]
    arr = np.stack([np.asarray(t_in, np.int32), np.asarray(t_out, np.int32)])
    pad = max_triplets - arr.shape[1]
    if pad > 0:
        arr = np.pad(arr, ((0, 0), (0, pad)), mode="edge")
    return arr


def recsys_batch(step: int, batch: int, n_fields: int, rows_per_field: int) -> dict:
    rng = np.random.default_rng(987 + step)
    return {
        "ids": rng.integers(0, rows_per_field, (batch, n_fields)).astype(np.int32),
        "labels": rng.integers(0, 2, batch).astype(np.float32),
    }
