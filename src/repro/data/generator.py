"""Clique-injected synthetic KG families mirroring the paper's five datasets.

The paper evaluates on Claros / DBpedia / OpenCyc / UniProt / UOBM, whose
shared structural features are: (a) owl:sameAs triples derived DURING
materialisation (inverse-functional-style rules), (b) DL-style rule programs
(property chains, symmetric/transitive properties, hierarchies), and (c) very
different equality densities — from 5 merges (UniProt) to 361k (OpenCyc).

Each profile below reproduces those regimes at CPU-runnable scale (the knobs
are documented next to the paper dataset they imitate); bench_materialisation
reports the same columns as the paper's Table 2 on them.

Structure: entities are partitioned into k duplicate-groups ("the same
real-world thing registered n times").  Each duplicate carries an
:idProp value shared by its group; the rule

    <x, owl:sameAs, y> <- <x, :idProp, v> & <y, :idProp, v>

(an inverse-functional property, the dominant real-world source of sameAs)
derives the cliques during materialisation, exactly like rule (R)/(S) of the
paper's running example.  Spoke triples hang off duplicates so that merges
"copy" payload triples under AX.  Optional extras per profile:

  * symmetric+transitive :sameHomeTown (the UOBM quadratic-derivation trap),
  * a class hierarchy (type-propagation chains like Claros/OpenCyc),
  * a property chain rule (DBpedia-style join rules),
  * entity-constant rules (``const_rules``): rules whose body references a
    specific clique member by ID, so that merging its clique rewrites the
    rule itself — rho(P) changes, Algorithm 1's queue R fills, and the
    forward-side re-merge machinery is exercised (the ``merge_like``
    profile drives the ``full_plan_evals == 0`` acceptance gate with it).
"""

from __future__ import annotations

import numpy as np

from repro.core.rules import Program, parse_program
from repro.core.terms import Dictionary

__all__ = ["generate", "sample_update_stream", "PROFILES"]


def generate(
    n_groups: int = 200,
    group_size: int = 4,
    n_spokes_per: int = 3,
    n_plain: int = 2000,
    n_classes: int = 12,
    hierarchy_depth: int = 3,
    hometown_groups: int = 0,
    hometown_size: int = 0,
    chain_rules: bool = False,
    const_rules: int = 0,
    seed: int = 0,
) -> tuple[np.ndarray, Program, Dictionary]:
    """Returns (facts (N,3) int32, program, dictionary)."""
    rng = np.random.default_rng(seed)
    dic = Dictionary()
    sa = "owl:sameAs"  # parsed rules intern it consistently

    rules = [
        # inverse-functional id => sameAs (the clique generator)
        f"(?x, {sa}, ?y) <- (?x, :idProp, ?v) & (?y, :idProp, ?v)",
    ]
    if hierarchy_depth > 0:
        for lvl in range(hierarchy_depth):
            rules.append(
                f"(?x, rdf:type, :C{lvl + 1}) <- (?x, rdf:type, :C{lvl})"
            )
    if hometown_groups > 0:
        rules += [
            "(?y, :sameHomeTown, ?x) <- (?x, :sameHomeTown, ?y)",
            "(?x, :sameHomeTown, ?z) <- (?x, :sameHomeTown, ?y) & (?y, :sameHomeTown, ?z)",
        ]
    if chain_rules:
        rules += [
            "(?x, :colleagueOf, ?z) <- (?x, :worksAt, ?y) & (?z, :worksAt, ?y)",
            "(?x, :related, ?y) <- (?x, :colleagueOf, ?y)",
        ]
    program = parse_program(rules, dic)

    id_prop = dic.intern(":idProp")
    rdf_type = dic.intern("rdf:type")
    spoke = dic.intern(":spoke")
    works_at = dic.intern(":worksAt")
    home = dic.intern(":sameHomeTown")
    classes = dic.intern_many([f":C{i}" for i in range(hierarchy_depth + 1)])

    rows: list[tuple[int, int, int]] = []

    # duplicate groups -> cliques via :idProp
    for g in range(n_groups):
        vid = dic.intern(f":idval{g}")
        members = dic.intern_many([f":e{g}_{i}" for i in range(group_size)])
        for m in members:
            rows.append((m, id_prop, vid))
            rows.append((m, rdf_type, classes[0]))
        for j in range(n_spokes_per):
            s = dic.intern(f":spoke{g}_{j}")
            rows.append((s, spoke, members[j % group_size]))

    # entity-constant rules: each references its group's LAST member (the
    # highest-ID clique member, interned above in fact order), so rho — whose
    # representative is the clique minimum — rewrites the rule constant on
    # the in-group merge and again whenever an update merges the clique into
    # a lower-ID one.  Parsed AFTER the group entities so the constant is the
    # already-interned member, not a fresh low-ID resource that would win
    # representative election and never be rewritten.
    if const_rules > 0:
        const_lines = [
            f"(?s, :anchored, :A{k}) <- (?s, :spoke, :e{k}_{group_size - 1})"
            for k in range(min(const_rules, n_groups))
        ]
        program = Program(program.rules + parse_program(const_lines, dic).rules)

    # plain (merge-free) payload triples
    ents = dic.intern_many([f":p{i}" for i in range(max(n_plain // 4, 1))])
    orgs = dic.intern_many([f":org{i}" for i in range(max(n_plain // 40, 1))])
    props = dic.intern_many([":knows", ":near", ":partOf"])
    for _ in range(n_plain):
        s = ents[rng.integers(len(ents))]
        p = props[rng.integers(len(props))]
        o = ents[rng.integers(len(ents))]
        rows.append((s, p, o))
    if chain_rules:
        for e in ents:
            rows.append((e, works_at, orgs[rng.integers(len(orgs))]))

    # UOBM-style symmetric+transitive hometown groups (quadratic derivations
    # that rewriting does NOT remove — the paper's UOBM analysis)
    for hg in range(hometown_groups):
        ppl = dic.intern_many([f":ht{hg}_{i}" for i in range(hometown_size)])
        for i in range(hometown_size - 1):
            rows.append((ppl[i], home, ppl[i + 1]))

    facts = np.asarray(rows, dtype=np.int32)
    return facts, program, dic


def _sample_query(rng, current, dic: Dictionary):
    """A random BGP query over the stream's current explicit facts.

    Shapes exercise the paper's §5 hazards against a *live* store: a
    projected-out join variable (clique-size multiplicities), a two-pattern
    join through a shared variable, and a constant pattern whose resource
    must be rho-normalised at the epoch the query is served at.  Variables
    are the executor's negative IDs (?x=-1, ?y=-2, ?z=-3).
    """
    from repro.sparql.algebra import Query

    if not current:
        return Query([(-1, dic.intern(":idProp"), -2)], [], [-1], False)
    _s, p, o = current[rng.integers(len(current))]
    kind = int(rng.integers(3))
    if kind == 0:  # bag semantics: ?y projected out -> clique multiplicities
        patterns, select = [(-1, p, -2)], [-1]
    elif kind == 1:  # join through a shared variable
        patterns, select = [(-1, p, -2), (-3, p, -2)], [-1, -3]
    else:  # constant object: normalised under the serving epoch's rho
        patterns, select = [(-1, p, int(o))], [-1]
    return Query(patterns, [], select, distinct=bool(rng.random() < 0.3))


def sample_update_stream(
    facts: np.ndarray,
    dic: Dictionary,
    n_events: int = 6,
    batch: int = 24,
    p_delete: float = 0.5,
    p_merge_add: float = 0.4,
    p_query: float = 0.0,
    seed: int = 0,
) -> list[tuple[str, object]]:
    """Sample an update stream for incremental-maintenance workloads.

    Returns ``[(op, payload), ...]`` with ``op in {"add", "delete"}``, each
    payload an (m, 3) int32 batch of explicit triples, consistent as a
    sequence (deletions only target facts explicit at that point).  The
    additions deliberately include fresh ``:idProp`` edges between existing
    entities — under the generator's inverse-functional rule those derive
    *new sameAs merges*, and their later deletion forces clique splits, the
    hard paths of ``repro.core.incremental``.  Plain payload additions
    reuse existing resources so updates interact with the standing store.

    With ``p_query > 0`` the trace is a mixed *serving* workload: events may
    also be ``("query", repro.sparql.Query)`` — read-only queries sampled
    over the stream's current explicit facts that a live store answers at
    whatever maintenance epoch the scheduler has completed when they are
    admitted (repro.serve.triple_store).  Queries never mutate the stream.
    """
    rng = np.random.default_rng(seed)
    current: list[tuple[int, int, int]] = [tuple(map(int, r)) for r in facts]
    id_prop = dic.intern(":idProp")
    events: list[tuple[str, object]] = []
    n_upd_vals = 0

    for ev in range(n_events):
        if p_query > 0 and rng.random() < p_query:
            events.append(("query", _sample_query(rng, current, dic)))
            continue
        do_delete = current and rng.random() < p_delete
        if do_delete:
            m = min(batch, len(current))
            idx = rng.choice(len(current), size=m, replace=False)
            delta = np.asarray([current[i] for i in idx], dtype=np.int32)
            keep = np.ones(len(current), dtype=bool)
            keep[idx] = False
            current = [row for row, k in zip(current, keep) if k]
            events.append(("delete", delta))
            continue
        subjects = sorted({r[0] for r in current})
        if len(subjects) < 2:  # (re)bootstrap an emptied stream
            subjects += dic.intern_many([f":seed{ev}_{i}" for i in range(2)])
        rows: list[tuple[int, int, int]] = []
        for _ in range(batch):
            if not current or rng.random() < p_merge_add:
                # fresh inverse-functional value shared by two existing
                # entities -> derives a new sameAs merge when applied
                a, b = rng.choice(len(subjects), size=2, replace=False)
                vid = dic.intern(f":updval{n_upd_vals}")
                n_upd_vals += 1
                rows.append((subjects[a], id_prop, vid))
                rows.append((subjects[b], id_prop, vid))
            else:
                src = current[rng.integers(len(current))]
                s = subjects[rng.integers(len(subjects))]
                rows.append((s, src[1], src[2]))
        delta = np.unique(np.asarray(rows, dtype=np.int32), axis=0)
        current.extend(tuple(map(int, r)) for r in delta)
        events.append(("add", delta))
    return events


# Reduced-scale stand-ins for the paper's datasets (Table 2 rows).
PROFILES: dict[str, dict] = {
    # Claros: mid-size, many sameAs merges, deep type hierarchy
    "claros_like": dict(
        n_groups=300, group_size=6, n_spokes_per=4, n_plain=4000,
        hierarchy_depth=4,
    ),
    # DBpedia: large plain payload, few merges
    "dbpedia_like": dict(
        n_groups=60, group_size=3, n_spokes_per=2, n_plain=20000,
        hierarchy_depth=2, chain_rules=True,
    ),
    # OpenCyc: equality-dense — many big cliques, little payload
    "opencyc_like": dict(
        n_groups=500, group_size=8, n_spokes_per=2, n_plain=1500,
        hierarchy_depth=3,
    ),
    # UniProt: almost no equalities, heavy payload + chains
    "uniprot_like": dict(
        n_groups=2, group_size=2, n_spokes_per=1, n_plain=25000,
        hierarchy_depth=2, chain_rules=True,
    ),
    # UOBM: few merges + symmetric/transitive hometown cluster
    "uobm_like": dict(
        n_groups=40, group_size=3, n_spokes_per=2, n_plain=3000,
        hierarchy_depth=2, hometown_groups=4, hometown_size=24,
    ),
    # Round-count extremes for the fused-fixpoint dispatch gate
    # (BENCH_incremental's dispatches_per_event).  Chain: almost no
    # merges, deep hierarchy + chain rules => long multi-round forward
    # convergence per event.  Clique: merge-dense, shallow payload =>
    # rounds dominated by the sameAs machinery and overdelete waves.
    "chain_like": dict(
        n_groups=2, group_size=3, n_spokes_per=1, n_plain=6000,
        hierarchy_depth=5, chain_rules=True,
    ),
    "clique_like": dict(
        n_groups=400, group_size=6, n_spokes_per=2, n_plain=1000,
        hierarchy_depth=1,
    ),
    # Merge-heavy stream against entity-constant rules: update merges that
    # relabel a referenced clique member rewrite rho(P) mid-stream, driving
    # the forward-side targeted re-merge path (and the full_plan_evals == 0
    # acceptance gate) rather than only the delete-side rederive machinery.
    "merge_like": dict(
        n_groups=48, group_size=4, n_spokes_per=3, n_plain=600,
        hierarchy_depth=1, const_rules=12,
    ),
}
