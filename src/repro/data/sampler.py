"""Fanout neighbour sampler for ``minibatch_lg`` (GraphSAGE-style).

CSR-backed uniform sampling with replacement; produces a fixed-size padded
subgraph (static shapes for jit): seeds + fanout[0] neighbours + fanout[1]
second-hop neighbours, with local node re-indexing.
"""

from __future__ import annotations

import numpy as np


class NeighborSampler:
    def __init__(self, n_nodes: int, edge_index: np.ndarray):
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        self.nbr = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def _sample_neighbors(self, rng, nodes: np.ndarray, k: int):
        lo = self.indptr[nodes]
        hi = self.indptr[nodes + 1]
        deg = np.maximum(hi - lo, 1)
        pick = rng.integers(0, deg[:, None], (nodes.shape[0], k))
        idx = np.minimum(lo[:, None] + pick, np.maximum(hi[:, None] - 1, lo[:, None]))
        return self.nbr[idx]  # (n, k); isolated nodes self-sample via clamp

    def sample(self, rng: np.random.Generator, seeds: np.ndarray, fanout=(15, 10)):
        """Returns (sub_nodes, sub_edge_index, seed_positions); fixed sizes
        n_sub = s*(1 + f0 + f0*f1), e_sub = s*f0 + s*f0*f1."""
        s = seeds.shape[0]
        h1 = self._sample_neighbors(rng, seeds, fanout[0])  # (s, f0)
        h2 = self._sample_neighbors(rng, h1.reshape(-1), fanout[1])  # (s*f0, f1)
        nodes = np.concatenate([seeds, h1.reshape(-1), h2.reshape(-1)])
        uniq, inv = np.unique(nodes, return_inverse=True)
        n_sub = s * (1 + fanout[0] + fanout[0] * fanout[1])
        # pad the unique node set to the static cap
        pad = n_sub - uniq.shape[0]
        sub_nodes = np.pad(uniq, (0, max(0, pad)), mode="edge")[:n_sub]
        seed_pos = inv[:s].astype(np.int32)
        # edges: h1 -> seeds, h2 -> h1
        src1 = inv[s : s + s * fanout[0]]
        dst1 = np.repeat(inv[:s], fanout[0])
        src2 = inv[s + s * fanout[0] :]
        dst2 = np.repeat(src1, fanout[1])
        src = np.concatenate([src1, src2]).astype(np.int32)
        dst = np.concatenate([dst1, dst2]).astype(np.int32)
        return sub_nodes.astype(np.int32), np.stack([src, dst]), seed_pos
