"""pna [arXiv:2004.05718]: n_layers=4 d_hidden=75,
aggregators mean-max-min-std, scalers id-amp-atten."""

from repro.models.gnn.pna import PNAConfig

from .base import GNN_SHAPES, ArchSpec

CONFIG = PNAConfig(name="pna", n_layers=4, d_hidden=75)
REDUCED = PNAConfig(name="pna-reduced", n_layers=2, d_hidden=15, d_in=32, n_classes=5)

SPEC = ArchSpec(
    name="pna",
    family="gnn",
    config=CONFIG,
    reduced=REDUCED,
    shapes=GNN_SHAPES,
    source="arXiv:2004.05718; paper",
)
