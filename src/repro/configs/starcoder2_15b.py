"""starcoder2-15b [arXiv:2402.19173]: 40L d=6144 48H (GQA kv=4) d_ff=24576,
vocab=49152, RoPE."""

from repro.models.transformer import LMConfig

from .base import LM_SHAPES, ArchSpec

CONFIG = LMConfig(
    name="starcoder2-15b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=4,
    d_head=128,
    d_ff=24_576,
    vocab=49_152,
    rope_theta=1e5,
)

REDUCED = LMConfig(
    name="starcoder2-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=256,
    vocab=256,
)

SPEC = ArchSpec(
    name="starcoder2-15b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=LM_SHAPES,
    source="arXiv:2402.19173; hf",
)
