"""ArchSpec: the (config x shapes) contract consumed by smoke tests and the
multi-pod dry-run.

Each shape entry:
  kind   — 'train' (lowers train_step), 'prefill'/'decode'/'serve'
           (lower serve paths), 'engine' (materialisation round),
  dims   — shape-specific sizes,
  skip   — reason string when the cell is skipped per assignment rules
           (e.g. long_500k on pure full-attention archs).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str
    dims: dict
    skip: str | None = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str  # 'lm' | 'gnn' | 'recsys' | 'engine'
    config: Any
    reduced: Any
    shapes: tuple[ShapeSpec, ...]
    source: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name!r}")


LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec(
        "long_500k",
        "decode",
        dict(seq_len=524288, global_batch=1),
        skip="pure full-attention arch: long_500k designated for sub-quadratic "
        "attention per assignment (DESIGN.md §4)",
    ),
)

GNN_SHAPES = (
    ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433),
    ),
    ShapeSpec(
        "minibatch_lg", "train",
        dict(
            n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
            fanout=(15, 10),
            # sampled-subgraph caps: 1024 seeds, 15 then 10 neighbours
            sub_nodes=1024 * (1 + 15 + 150), sub_edges=1024 * 15 + 1024 * 15 * 10,
        ),
    ),
    ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    ),
    ShapeSpec(
        "molecule", "train",
        dict(n_nodes=30, n_edges=64, batch=128),
    ),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", dict(batch=65_536)),
    ShapeSpec("serve_p99", "serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "serve", dict(batch=262_144)),
    ShapeSpec("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)
