"""gatedgcn [arXiv:2003.00982]: n_layers=16 d_hidden=70, gated aggregation."""

from repro.models.gnn.gatedgcn import GatedGCNConfig

from .base import GNN_SHAPES, ArchSpec

CONFIG = GatedGCNConfig(name="gatedgcn", n_layers=16, d_hidden=70)
REDUCED = GatedGCNConfig(
    name="gatedgcn-reduced", n_layers=3, d_hidden=16, d_in=32, n_classes=5
)

SPEC = ArchSpec(
    name="gatedgcn",
    family="gnn",
    config=CONFIG,
    reduced=REDUCED,
    shapes=GNN_SHAPES,
    source="arXiv:2003.00982; paper",
)
