"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 94L d=4096 64H (GQA kv=4)
MoE 128 experts top-8, expert d_ff=1536, vocab=151936, head_dim=128."""

from repro.models.transformer import LMConfig

from .base import LM_SHAPES, ArchSpec

CONFIG = LMConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv=4,
    d_head=128,
    d_ff=0,
    vocab=151_936,
    n_experts=128,
    top_k=8,
    n_shared=0,
    d_expert=1536,
    rope_theta=1e6,
    # 235B bf16 at TP16 is 29 GiB/chip — params must also shard over data
    fsdp=True,
    # 94-layer residual stack is ~3 GiB/chip bf16; pairwise remat halves it
    remat_group=1,
)

REDUCED = LMConfig(
    name="qwen3-moe-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=0,
    vocab=256,
    n_experts=8,
    top_k=2,
    n_shared=0,
    d_expert=32,
)

SPEC = ArchSpec(
    name="qwen3-moe-235b-a22b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen3-30B-A3B scaled per assignment; hf",
)
