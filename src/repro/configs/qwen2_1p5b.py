"""qwen2-1.5b [arXiv:2407.10671]: 28L d=1536 12H (GQA kv=2) d_ff=8960,
vocab=151936, QKV bias."""

from repro.models.transformer import LMConfig

from .base import LM_SHAPES, ArchSpec

CONFIG = LMConfig(
    name="qwen2-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_head=128,
    d_ff=8960,
    vocab=151_936,
    qkv_bias=True,
    rope_theta=1e6,
)

REDUCED = LMConfig(
    name="qwen2-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
)

SPEC = ArchSpec(
    name="qwen2-1.5b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=LM_SHAPES,
    source="arXiv:2407.10671; hf",
)
