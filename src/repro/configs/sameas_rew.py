"""sameas_rew — the paper's own workload as a dry-run architecture.

One SPMD materialisation round (process_candidates + a representative
two-atom join plan) lowered on the production mesh, with the triple arena
sharded over (pod x data) and rho replicated.  Dims are per-DEVICE
capacities; the global arena is capacity x n_devices triples.
"""

import dataclasses

from .base import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    name: str = "sameas_rew"
    n_resources: int = 1 << 20
    capacity: int = 1 << 18        # per-device arena rows
    bind_cap: int = 1 << 14
    out_cap: int = 1 << 14
    rewrite_cap: int = 1 << 14
    # owner-routing bucket rows per destination shard (None = all-gather)
    route_cap: int | None = 1 << 12
    # replicated query rows per tombstone-seed / membership probe batch
    # (the incremental update path; JaxEngine.from_config plumbs it through)
    seed_chunk: int = 2048
    # out rows per delta/tomb plan during incremental updates (None = derive
    # from out_cap); full-evaluation plans always use out_cap
    delta_out_cap: int | None = None


CONFIG = EngineConfig()
REDUCED = EngineConfig(
    name="sameas_rew-reduced",
    n_resources=1 << 10,
    capacity=256,
    bind_cap=256,
    out_cap=256,
    rewrite_cap=256,
    route_cap=64,
    seed_chunk=64,
)

SHAPES = (
    # global arena = capacity x 256 (single pod) / x 512 (multi-pod)
    ShapeSpec("round_67m", "engine", dict(capacity=1 << 18, n_resources=1 << 20)),
    ShapeSpec("round_268m", "engine", dict(capacity=1 << 20, n_resources=1 << 21)),
)

SPEC = ArchSpec(
    name="sameas_rew",
    family="engine",
    config=CONFIG,
    reduced=REDUCED,
    shapes=SHAPES,
    source="this paper",
)
