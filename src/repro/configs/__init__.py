"""Architecture registry: one module per assigned architecture.

``get_arch(name)`` returns the ArchSpec; ``all_archs()`` lists them in the
assignment order (plus ``sameas_rew`` — the paper's own engine workload).
"""

from __future__ import annotations

from .base import ArchSpec

_ARCH_MODULES = [
    "qwen3_moe_235b",
    "deepseek_moe_16b",
    "qwen2_1p5b",
    "smollm_135m",
    "starcoder2_15b",
    "dimenet",
    "egnn",
    "gatedgcn",
    "pna",
    "fm",
    "sameas_rew",
]


_ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-1.5b": "qwen2_1p5b",
    "smollm-135m": "smollm_135m",
    "starcoder2-15b": "starcoder2_15b",
}


def get_arch(name: str) -> ArchSpec:
    import importlib

    module = _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{module}")
    return mod.SPEC


def all_archs() -> list[str]:
    return list(_ARCH_MODULES)
