"""dimenet [arXiv:2003.03123]: n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6 — triplet-gather kernel regime."""

from repro.models.gnn.dimenet import DimeNetConfig

from .base import GNN_SHAPES, ArchSpec

CONFIG = DimeNetConfig(
    name="dimenet",
    n_blocks=6,
    d_hidden=128,
    n_bilinear=8,
    n_spherical=7,
    n_radial=6,
)

REDUCED = DimeNetConfig(
    name="dimenet-reduced",
    n_blocks=2,
    d_hidden=16,
    n_bilinear=4,
    n_spherical=3,
    n_radial=4,
)

SPEC = ArchSpec(
    name="dimenet",
    family="gnn",
    config=CONFIG,
    reduced=REDUCED,
    shapes=GNN_SHAPES,
    source="arXiv:2003.03123; unverified",
)
