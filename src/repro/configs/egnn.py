"""egnn [arXiv:2102.09844]: n_layers=4 d_hidden=64, E(n)-equivariant."""

from repro.models.gnn.egnn import EGNNConfig

from .base import GNN_SHAPES, ArchSpec

CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64)
REDUCED = EGNNConfig(name="egnn-reduced", n_layers=2, d_hidden=16)

SPEC = ArchSpec(
    name="egnn",
    family="gnn",
    config=CONFIG,
    reduced=REDUCED,
    shapes=GNN_SHAPES,
    source="arXiv:2102.09844; paper",
)
