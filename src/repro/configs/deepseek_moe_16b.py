"""deepseek-moe-16b [arXiv:2401.06066]: 28L d=2048 16H (MHA kv=16)
fine-grained MoE: 2 shared + 64 routed top-6, expert d_ff=1408."""

from repro.models.transformer import LMConfig

from .base import LM_SHAPES, ArchSpec

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=0,
    vocab=102_400,
    n_experts=64,
    top_k=6,
    n_shared=2,
    d_expert=1408,
    rope_theta=1e4,
)

REDUCED = LMConfig(
    name="deepseek-moe-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_head=16,
    d_ff=0,
    vocab=256,
    n_experts=8,
    top_k=3,
    n_shared=1,
    d_expert=32,
)

SPEC = ArchSpec(
    name="deepseek-moe-16b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=LM_SHAPES,
    source="arXiv:2401.06066; hf",
)
