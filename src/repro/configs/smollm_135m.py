"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M]: 30L d=576 9H (GQA kv=3)
d_ff=1536, vocab=49152 (llama-arch small)."""

from repro.models.transformer import LMConfig

from .base import LM_SHAPES, ArchSpec

CONFIG = LMConfig(
    name="smollm-135m",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_head=64,
    d_ff=1536,
    vocab=49_152,
    rope_theta=1e4,
)

REDUCED = LMConfig(
    name="smollm-reduced",
    n_layers=3,
    d_model=48,
    n_heads=3,
    n_kv=3,
    d_head=16,
    d_ff=96,
    vocab=256,
)

SPEC = ArchSpec(
    name="smollm-135m",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=LM_SHAPES,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)
