"""fm [Rendle ICDM'10]: 39 sparse fields, embed_dim=10, 2-way interactions
via the O(nk) sum-square trick; Criteo-scale tables."""

from repro.models.recsys import FMConfig

from .base import RECSYS_SHAPES, ArchSpec

CONFIG = FMConfig(name="fm", n_fields=39, embed_dim=10, rows_per_field=865_707)
REDUCED = FMConfig(name="fm-reduced", n_fields=8, embed_dim=4, rows_per_field=100)

SPEC = ArchSpec(
    name="fm",
    family="recsys",
    config=CONFIG,
    reduced=REDUCED,
    shapes=RECSYS_SHAPES,
    source="ICDM'10 (Rendle); paper",
)
