"""Jaxpr traversal + the invariant passes of the trace-audit subsystem.

Each pass encodes one hot-path contract of the engine as a predicate over
the *compiled* representation — the jaxpr — rather than over the source:
refactors cannot silently reintroduce an arena-length sort or an int32 key
truncation without the audit (CI-gated via ``python -m repro.analysis
--check``) catching it at the primitive level.  See docs/analysis.md for
the contract each pass encodes and how to add a new one.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

from jax.core import Jaxpr

try:  # pragma: no cover - layout differs across jax lines
    from jax.core import ClosedJaxpr
except ImportError:  # pragma: no cover - newer jax
    from jax.extend.core import ClosedJaxpr


# ---------------------------------------------------------------------------
# generic traversal
# ---------------------------------------------------------------------------

def sub_jaxprs(params: dict):
    """Every (sub)jaxpr reachable from an eqn's params, with its param key.

    Handles the shapes the engine's fns actually produce — ``pjit`` /
    ``closed_call`` (a single ClosedJaxpr under ``jaxpr``), ``scan`` /
    ``while`` (``jaxpr`` / ``cond_jaxpr`` + ``body_jaxpr``), ``cond``
    (a *tuple* of branch ClosedJaxprs), ``shard_map`` / ``custom_*`` calls
    — plus arbitrary list/tuple/dict nesting, which the historical ad-hoc
    helper (``tests/test_index_invariant._sub_jaxprs``) missed.  Yields
    ``(key, jaxpr)`` pairs with ClosedJaxprs unwrapped.
    """

    def visit(key, v):
        if isinstance(v, ClosedJaxpr):
            yield key, v.jaxpr
        elif isinstance(v, Jaxpr):
            yield key, v
        elif isinstance(v, (list, tuple)):
            for i, x in enumerate(v):
                yield from visit(f"{key}[{i}]", x)
        elif isinstance(v, dict):
            for k, x in v.items():
                yield from visit(f"{key}.{k}", x)

    for key, v in params.items():
        yield from visit(key, v)


def jaxpr_walk(jaxpr, path: tuple = ()):
    """Yield ``(eqn, path)`` for every eqn of ``jaxpr`` and all sub-jaxprs.

    ``path`` is the nesting trail of ``primitive[param_key]`` strings — a
    human-readable location for violation reports (and precise enough to
    find the eqn again).  Accepts a ClosedJaxpr or a Jaxpr.
    """
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, path
        for key, sub in sub_jaxprs(eqn.params):
            yield from jaxpr_walk(sub, path + (f"{eqn.primitive.name}[{key}]",))


def _fmt_path(path: tuple) -> str:
    return "/".join(path) if path else "<top>"


def _leading_dim(aval) -> int:
    shape = getattr(aval, "shape", ())
    return int(shape[0]) if shape else 0


def count_sorts_at_least(jaxpr, n_rows: int) -> int:
    """Count sort eqns (recursively) whose operands reach ``n_rows`` rows.

    The shared replacement for the historical per-test helper: the count
    the no-arena-sort budget tests pin, expressed over :func:`jaxpr_walk`
    so nested ``cond`` branches / ``shard_map`` bodies are covered too.
    """
    return sum(
        1
        for eqn, _path in jaxpr_walk(jaxpr)
        if eqn.primitive.name == "sort"
        and any(_leading_dim(v.aval) >= n_rows for v in eqn.invars)
    )


# ---------------------------------------------------------------------------
# pass framework
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Violation:
    """One invariant violation found in a traced fn."""

    pass_name: str
    fn: str          # label of the audited fn (registry name + variant)
    primitive: str   # offending primitive name
    path: str        # nesting trail inside the jaxpr ("<top>" if top-level)
    detail: str      # human-readable explanation with the relevant shapes

    def as_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:  # the CLI's one-line form
        return (
            f"[{self.pass_name}] {self.fn}: {self.primitive} at {self.path}"
            f" — {self.detail}"
        )


class AnalysisPass:
    """Base class: subclasses set ``name`` and implement :meth:`run`.

    ``run(fn_label, jaxpr, arena_rows)`` returns the violations found;
    ``arena_rows`` is the traced state's arena length — the threshold the
    length-sensitive passes compare leading dimensions against (the probe
    geometry keeps it strictly larger than every other buffer, so crossing
    it is unambiguous).
    """

    name: str = "base"

    def run(self, fn: str, jaxpr, arena_rows: int) -> list[Violation]:
        raise NotImplementedError

    def _v(self, fn, eqn, path, detail) -> Violation:
        return Violation(self.name, fn, eqn.primitive.name, _fmt_path(path), detail)


class NoArenaSort(AnalysisPass):
    """No sort/argsort over arena-length operands in delta-path fns.

    The persistent sorted index (PR 4) exists precisely so that membership
    probes and joins never re-sort the arena; the only allowed full argsort
    lives in the explicit rebuild fn (registered with this pass skipped).
    jnp.argsort lowers to the same ``sort`` primitive (keys + iota
    operands), so one check covers both.
    """

    name = "NoArenaSort"

    def run(self, fn, jaxpr, arena_rows):
        out = []
        for eqn, path in jaxpr_walk(jaxpr):
            if eqn.primitive.name != "sort":
                continue
            dims = [_leading_dim(v.aval) for v in eqn.invars]
            if any(d >= arena_rows for d in dims):
                out.append(self._v(
                    fn, eqn, path,
                    f"sort over {max(dims)} rows >= arena ({arena_rows}) — "
                    "hot-path joins must sort binding tables, never the arena",
                ))
        return out


class NoArenaScatter(AnalysisPass):
    """No scatter with arena-length updates/indices in delta-path fns.

    Swept/finalised rows leave the index by stable partition (cumsum +
    binary-searched gather) and fresh rows rank-merge in; a scatter whose
    updates stream reaches arena length would reintroduce the per-round
    arena-proportional write traffic those replaced.  The per-``n_res``
    mask reductions of the DRed wave fns scatter arena-length updates by
    design and register with this pass skipped.
    """

    name = "NoArenaScatter"

    def run(self, fn, jaxpr, arena_rows):
        out = []
        for eqn, path in jaxpr_walk(jaxpr):
            if not eqn.primitive.name.startswith("scatter"):
                continue
            # invars = (operand, scatter_indices, updates): the *stream*
            # side is what must stay delta-width — an arena-sized operand
            # being updated in place (insertion) is fine
            dims = [_leading_dim(v.aval) for v in eqn.invars[1:]]
            if any(d >= arena_rows for d in dims):
                out.append(self._v(
                    fn, eqn, path,
                    f"scatter updates {max(dims)} rows >= arena "
                    f"({arena_rows}) — delta-path scatters must scale with "
                    "the update stream",
                ))
        return out


class DtypeSafety(AnalysisPass):
    """Packed int64 keys must never be truncated to a narrower dtype.

    Packed triple keys need 63 bits (3 x 21-bit IDs); a silent
    ``astype(int32)`` of a pack product corrupts every comparison
    downstream while staying bit-identical on small test IDs — the exact
    class of bug a unit test won't catch.  Implemented as a per-jaxpr
    taint analysis: any int64 ``shift_left`` seeds a taint (the packing
    idiom), taint propagates through value-preserving primitives (or/and,
    select, gather, sort, concatenate, ...), and a ``convert_element_type``
    to a narrower dtype on a tainted value is flagged.  Each sub-jaxpr is
    analysed independently (fresh seeds), so nested packing is covered
    without cross-call dataflow.
    """

    name = "DtypeSafety"

    # primitives through which a packed key flows unchanged in value-width
    _PROPAGATE = frozenset({
        "or", "and", "xor", "add", "sub", "max", "min", "select_n",
        "gather", "slice", "dynamic_slice", "squeeze", "reshape",
        "broadcast_in_dim", "concatenate", "transpose", "rev", "pad",
        "expand_dims", "copy", "clamp", "where",
    })

    def run(self, fn, jaxpr, arena_rows):
        out = []
        self._scan(fn, jaxpr, (), out)
        return out

    def _scan(self, fn, jaxpr, path, out):
        if isinstance(jaxpr, ClosedJaxpr):
            jaxpr = jaxpr.jaxpr
        taint: set = set()

        def tainted(v):
            # literals are never tainted; vars hash by identity
            return not hasattr(v, "val") and v in taint

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "shift_left" and any(
                str(getattr(o.aval, "dtype", "")) == "int64"
                for o in eqn.outvars
            ):
                taint.update(eqn.outvars)
            elif name == "convert_element_type" and any(map(tainted, eqn.invars)):
                src = eqn.invars[0].aval.dtype
                dst = eqn.params.get("new_dtype", src)
                if dst.itemsize < src.itemsize:
                    out.append(Violation(
                        self.name, fn, name, _fmt_path(path),
                        f"packed {src} key truncated to {dst} — 63-bit "
                        "packed triple keys must stay int64 end to end",
                    ))
                else:
                    taint.update(eqn.outvars)
            elif name == "sort" and any(map(tainted, eqn.invars)):
                # operand-wise: the sorted key column stays tainted, the
                # co-sorted iota/index columns do not
                for iv, ov in zip(eqn.invars, eqn.outvars):
                    if tainted(iv):
                        taint.add(ov)
            elif name in self._PROPAGATE and any(map(tainted, eqn.invars)):
                if name == "gather":
                    if tainted(eqn.invars[0]):
                        taint.update(eqn.outvars)
                else:
                    taint.update(eqn.outvars)
            for key, sub in sub_jaxprs(eqn.params):
                self._scan(fn, sub, path + (f"{name}[{key}]",), out)


class NoHostCallback(AnalysisPass):
    """No host callback primitives inside hot compiled fns.

    ``io_callback`` / ``debug_callback`` / ``pure_callback`` force a
    device-to-host round trip per invocation — inside a maintenance round
    fn that multiplies straight into the per-event dispatch floor the
    ROADMAP is trying to kill.  Debug prints left behind in a hot fn are
    the common offender.
    """

    name = "NoHostCallback"

    _CALLBACKS = frozenset({"io_callback", "debug_callback", "pure_callback"})

    def run(self, fn, jaxpr, arena_rows):
        out = []
        for eqn, path in jaxpr_walk(jaxpr):
            if eqn.primitive.name in self._CALLBACKS:
                out.append(self._v(
                    fn, eqn, path,
                    "host callback inside a compiled hot fn — one "
                    "device-to-host round trip per dispatch",
                ))
        return out


ALL_PASSES: tuple[AnalysisPass, ...] = (
    NoArenaSort(),
    NoArenaScatter(),
    DtypeSafety(),
    NoHostCallback(),
)
