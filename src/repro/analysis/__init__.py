"""Trace-audit subsystem: jaxpr invariant linter + dispatch auditor.

The engine's hot-path guarantees — no arena-length sorts inside the round
fns, delta-width joins, packed keys staying int64, no host callbacks inside
compiled code, a bounded number of compiled-call dispatches per maintenance
phase — were informal discipline plus one ad-hoc trace test.  This package
machine-checks them as *static analysis passes over jaxprs*:

  * :func:`jaxpr_walk` — generic recursive traversal of a jaxpr and every
    sub-jaxpr reachable through eqn params (``pjit``/``closed_call`` bodies,
    ``scan``/``while`` carries, every ``cond`` branch, ``shard_map`` bodies,
    arbitrarily nested containers), yielding ``(eqn, path)`` pairs;
  * :mod:`repro.analysis.passes` — pluggable passes over the walk
    (``NoArenaSort``, ``NoArenaScatter``, ``DtypeSafety``,
    ``NoHostCallback``), each returning :class:`Violation` records that name
    the pass, the audited fn, the offending primitive and its nesting path;
  * the **inventory** — every auditable engine/maintenance fn registers a
    trace builder in ``repro.core.engine_jax.AUDIT_REGISTRY``
    (:func:`repro.core.engine_jax.register_auditable`); :func:`audit_engine`
    traces the full registry at a *probe geometry* (arena strictly larger
    than every other buffer, so "arena-length" is unambiguous in the
    traces) and runs every applicable pass;
  * the **dispatch auditor** — :func:`static_dispatch_profile` (in
    :mod:`repro.core.incremental_spmd`) states which compiled-fn families
    each maintenance phase may dispatch and how many distinct compiled
    calls one round/wave costs; the runtime side is
    :class:`repro.core.stats.DispatchCounter` on ``JaxEngine.dispatches``
    (every fn-cache hit is counted under the phase the generators tag);
    :func:`dispatch_crosscheck` verifies observed (phase, family) dispatch
    pairs against the static profile.

``python -m repro.analysis --check`` audits the registered inventory and
exits nonzero on violations — the CI gate that turns the implicit perf
contracts into enforced ones (docs/analysis.md).
"""

from __future__ import annotations

from .passes import (
    ALL_PASSES,
    AnalysisPass,
    DtypeSafety,
    NoArenaScatter,
    NoArenaSort,
    NoHostCallback,
    Violation,
    count_sorts_at_least,
    jaxpr_walk,
    sub_jaxprs,
)

__all__ = [
    "ALL_PASSES",
    "AnalysisPass",
    "DtypeSafety",
    "NoArenaScatter",
    "NoArenaSort",
    "NoHostCallback",
    "Violation",
    "audit_engine",
    "build_probe",
    "count_sorts_at_least",
    "dispatch_crosscheck",
    "jaxpr_walk",
    "run_report",
    "sub_jaxprs",
]


# ---------------------------------------------------------------------------
# inventory audit
# ---------------------------------------------------------------------------

def build_probe(dataset: str = "pex", capacity: int = 4096, cap: int = 256):
    """A representative engine + materialised state for tracing the registry.

    The arena is strictly larger than every other buffer (asserted) so an
    arena-length operand is unambiguous in the traces — the same probe
    geometry the historical trace test used.  Returns
    ``(engine, state, program)``.
    """
    from repro.core.engine_jax import JaxEngine
    from repro.data.datasets import clique_with_spokes, pex, single_clique

    if dataset == "pex":
        facts, prog, dic = pex()
    elif dataset == "chain":
        facts, prog, dic = single_clique(8)
    elif dataset == "clique":
        facts, prog, dic = clique_with_spokes(6, 4)
    elif dataset == "dbpedia_like":
        from repro.data.generator import generate

        facts, prog, dic = generate(
            n_groups=2, group_size=3, n_spokes_per=2, n_plain=40,
            hierarchy_depth=2, chain_rules=True, seed=5,
        )
    else:
        raise ValueError(f"unknown probe dataset {dataset!r}")
    eng = JaxEngine(
        dic.n_resources, capacity=capacity, bind_cap=cap, out_cap=cap,
        rewrite_cap=cap,
    )
    state = eng.materialise_state(facts, prog)
    arena_rows = int(state.spo.shape[0])
    assert arena_rows > 4 * max(eng.bind_cap, eng.out_cap, eng.rewrite_cap), (
        "probe geometry degenerated: arena must dominate every buffer "
        f"(arena {arena_rows}, caps {eng.bind_cap}/{eng.out_cap}/"
        f"{eng.rewrite_cap}) — capacity growth during materialisation?"
    )
    return eng, state, prog


def audit_engine(engine, state, passes=None) -> list[Violation]:
    """Trace every registered auditable fn and run the applicable passes.

    The registry lives in :mod:`repro.core.engine_jax`
    (``AUDIT_REGISTRY``); :mod:`repro.core.incremental_spmd` registers its
    maintenance step fns on import.  Each entry may exempt itself from
    specific passes (e.g. the index rebuild fn IS the one allowed arena
    argsort).  ``arena_rows`` for the length thresholds is taken from the
    traced state.
    """
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental import enable_x64

    from repro.core import incremental_spmd  # noqa: F401  (registers fns)
    from repro.core.engine_jax import AUDIT_REGISTRY
    from repro.sparql import batched  # noqa: F401  (registers "bgp")

    passes = list(ALL_PASSES) if passes is None else list(passes)
    arena_rows = int(state.spo.shape[0])
    violations: list[Violation] = []
    with enable_x64():
        for spec in AUDIT_REGISTRY.values():
            for label, jx in spec.builder(engine, state):
                for p in passes:
                    if p.name in spec.skip_passes:
                        continue
                    violations += p.run(label, jx, arena_rows)
    return violations


def audited_fn_labels(engine, state) -> list[str]:
    """The labels of every traced fn in the registered inventory."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental import enable_x64

    from repro.core import incremental_spmd  # noqa: F401
    from repro.core.engine_jax import AUDIT_REGISTRY
    from repro.sparql import batched  # noqa: F401

    labels = []
    with enable_x64():
        for spec in AUDIT_REGISTRY.values():
            labels += [label for label, _ in spec.builder(engine, state)]
    return labels


# ---------------------------------------------------------------------------
# dispatch auditor (static profile x runtime counter cross-check)
# ---------------------------------------------------------------------------

def dispatch_crosscheck(counter, program=None) -> list[str]:
    """Verify runtime dispatches against the static per-phase profile.

    ``counter`` is a :class:`repro.core.stats.DispatchCounter` populated by
    running maintenance through the engine; every (phase, family) pair it
    observed must be admitted by
    :func:`repro.core.incremental_spmd.static_dispatch_profile` — a
    dispatch from an unregistered family inside a tagged phase means a
    compiled fn joined a hot path without declaring itself to the auditor.
    Dispatches outside any phase (``phase=None``: the base fixpoint,
    ad-hoc engine use) are not checked.  Returns problem strings
    (empty == consistent).
    """
    from repro.core.incremental_spmd import static_dispatch_profile

    profile = static_dispatch_profile(program)
    problems: list[str] = []
    for (phase, family), n in sorted(
        counter.by_phase.items(), key=lambda kv: (str(kv[0][0]), kv[0][1])
    ):
        if phase is None:
            continue
        allowed = profile.get(phase)
        if allowed is None:
            problems.append(
                f"dispatches under unknown phase {phase!r} (family {family} x{n})"
            )
        elif family not in allowed:
            problems.append(
                f"{phase}: dispatched unregistered fn family {family!r} x{n} "
                f"(static profile allows {sorted(allowed)})"
            )
    return problems


def run_report(dataset: str = "pex", events: int = 2) -> dict:
    """The full audit as a JSON-able report dict (the CLI / bench embed).

    Traces the registered inventory at the probe geometry and runs every
    pass; then drives ``events`` small maintenance operations (one add, one
    delete, alternating) through the engine so the runtime dispatch counter
    is populated, and cross-checks it against the static phase profile.
    """
    import numpy as np

    from repro.core.incremental_spmd import static_dispatch_profile

    engine, state, program = build_probe(dataset)
    violations = audit_engine(engine, state)
    labels = audited_fn_labels(engine, state)

    # drive a tiny update stream so every maintenance phase dispatches
    explicit = state.explicit
    for i in range(events):
        k = min(2, explicit.shape[0])
        rows = explicit[:k] if k else np.zeros((0, 3), np.int32)
        if i % 2 == 0 and rows.shape[0]:
            engine.delete_facts(state, rows)
        elif rows.shape[0]:
            engine.add_facts(state, rows)
        explicit = state.explicit
    dispatch_problems = dispatch_crosscheck(engine.dispatches, program)

    return {
        "dataset": dataset,
        "arena_rows": int(state.spo.shape[0]),
        "passes": [p.name for p in ALL_PASSES],
        "fns": sorted(labels),
        "violations": [v.as_dict() for v in violations],
        "dispatch": {
            "static_profile": {
                ph: dict(sorted(fams.items()))
                for ph, fams in static_dispatch_profile(program).items()
            },
            "runtime_by_family": dict(sorted(engine.dispatches.by_family.items())),
            "runtime_by_phase": {
                f"{ph}/{fam}": n
                for (ph, fam), n in sorted(
                    engine.dispatches.by_phase.items(),
                    key=lambda kv: (str(kv[0][0]), kv[0][1]),
                )
                if ph is not None
            },
            "compiles_by_family": dict(sorted(engine.dispatches.compiles.items())),
            "total": engine.dispatches.total,
            "problems": dispatch_problems,
        },
    }
