"""Planted-violation fixtures: toy fns each pass must catch.

Negative coverage for the audit — every fixture reproduces, in miniature,
the exact bug class its pass exists to block, at the same probe geometry
(``ARENA`` rows vs ``CAP``-width streams) the real audit traces at.  The
CLI's ``--fixture NAME`` mode and ``tests/test_analysis.py`` both trace
these and assert the expected pass fires with a useful location; a pass
that stops seeing its fixture is broken, whatever the inventory says.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ARENA = 4096   # the "arena" length of the toy fns
CAP = 256      # delta-stream width, strictly smaller


def arena_sort(keys, q):
    """Plants a NoArenaSort violation: re-sorts the full arena per probe.

    The pre-PR-4 membership idiom — ``argsort`` over all ``ARENA`` keys on
    every call instead of maintaining the persistent sorted index.
    """
    perm = jnp.argsort(keys)                       # <- arena-length sort
    srt = keys[perm]
    pos = jnp.searchsorted(srt, q, method="scan_unrolled")
    return srt[jnp.clip(pos, 0, ARENA - 1)] == q


def arena_scatter(dst, vals):
    """Plants a NoArenaScatter violation: arena-length updates stream.

    Rewrites every arena row per call — the write traffic the stable
    partition/rank-merge maintenance exists to avoid.
    """
    idx = jnp.arange(ARENA, dtype=jnp.int32)
    return dst.at[idx].max(vals)                   # <- arena-length scatter


def int32_key(s, p, o):
    """Plants a DtypeSafety violation: packed key truncated to int32.

    Packs 3 x 21-bit IDs into an int64 (the engine's ``_pack3`` idiom)
    then casts the product down — bit-identical on small test IDs,
    corrupt beyond 2^31.
    """
    key = (
        s.astype(jnp.int64) << jnp.int64(42)
    ) | (p.astype(jnp.int64) << jnp.int64(21)) | o.astype(jnp.int64)
    return key.astype(jnp.int32)                   # <- silent truncation


def host_callback(x):
    """Plants a NoHostCallback violation: a debug print left in a hot fn."""
    jax.debug.callback(lambda v: None, x[0])       # <- host round trip
    return x * 2


def nested_cond_sort(keys, q, flag):
    """Plants an arena sort inside a ``cond`` branch.

    Exercises the traversal depth the historical helper missed: the
    violation is only reachable through the branch tuple of a ``cond``
    eqn's params, so a walker that skips tuple-of-ClosedJaxpr params
    reports this fixture clean.
    """

    def probe(args):
        k, qq = args
        perm = jnp.argsort(k)                      # <- sort inside branch
        return k[perm][jnp.clip(qq, 0, ARENA - 1)]

    def skip(args):
        return jnp.int64(0)

    return jax.lax.cond(flag, probe, skip, (keys, q))


def _trace(fn, *avals):
    return jax.make_jaxpr(fn)(*avals)


def trace_fixture(name: str):
    """Trace a fixture by name; returns ``(label, jaxpr, arena_rows)``."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental import enable_x64

    i64 = jnp.int64
    i32 = jnp.int32
    with enable_x64():
        if name == "arena_sort":
            jx = _trace(arena_sort, jnp.zeros(ARENA, i64), jnp.zeros(CAP, i64))
        elif name == "arena_scatter":
            jx = _trace(
                arena_scatter, jnp.zeros(ARENA, i32), jnp.zeros(ARENA, i32)
            )
        elif name == "int32_key":
            jx = _trace(
                int32_key, jnp.zeros(CAP, i32), jnp.zeros(CAP, i32),
                jnp.zeros(CAP, i32),
            )
        elif name == "host_callback":
            jx = _trace(host_callback, jnp.zeros(CAP, i32))
        elif name == "nested_cond_sort":
            jx = _trace(
                nested_cond_sort, jnp.zeros(ARENA, i64), jnp.zeros((), i64),
                jnp.zeros((), jnp.bool_),
            )
        else:
            raise ValueError(f"unknown fixture {name!r} (have {FIXTURES})")
    return f"fixture:{name}", jx, ARENA


FIXTURES = (
    "arena_sort", "arena_scatter", "int32_key", "host_callback",
    "nested_cond_sort",
)

# the pass each fixture must trip — the CLI asserts the report names it
EXPECTED_PASS = {
    "arena_sort": "NoArenaSort",
    "arena_scatter": "NoArenaScatter",
    "int32_key": "DtypeSafety",
    "host_callback": "NoHostCallback",
    "nested_cond_sort": "NoArenaSort",
}
