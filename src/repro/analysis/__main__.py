"""``python -m repro.analysis`` — audit the compiled-fn inventory.

Modes:

  --check           audit the registered inventory at the probe geometry,
                    print violations + dispatch problems, exit nonzero on
                    any (the CI gate; seconds, CPU-only)
  --json PATH       also write the full report dict as JSON ("-" = stdout)
  --dataset NAME    probe dataset (pex | chain | clique | dbpedia_like)
  --fixture NAME    audit one planted-violation fixture instead of the
                    inventory; exits nonzero iff the expected pass fires —
                    i.e. rc != 0 means the audit is WORKING (the negative
                    self-test the acceptance criteria pin)
  --list-fns        print the audited fn labels and exit
"""

from __future__ import annotations

import argparse
import json
import sys


def _fail(msg: str) -> None:
    print(msg, file=sys.stderr)


def run_fixture(name: str, json_path: str | None) -> int:
    from . import ALL_PASSES
    from .fixtures import EXPECTED_PASS, trace_fixture

    label, jx, arena_rows = trace_fixture(name)
    violations = []
    for p in ALL_PASSES:
        violations += p.run(label, jx, arena_rows)
    report = {
        "fixture": name,
        "expected_pass": EXPECTED_PASS[name],
        "violations": [v.as_dict() for v in violations],
    }
    if json_path:
        _emit_json(report, json_path)
    for v in violations:
        print(v)
    hit = any(v.pass_name == EXPECTED_PASS[name] for v in violations)
    if not hit:
        _fail(
            f"fixture {name!r}: expected pass {EXPECTED_PASS[name]} did NOT "
            "fire — the audit has gone blind to this violation class"
        )
        # a blind audit is itself a failure, but distinguish it from the
        # found-the-plant exit the acceptance criteria check for
        return 2
    print(f"fixture {name!r}: {EXPECTED_PASS[name]} fired as planted")
    return 1


def _emit_json(report: dict, path: str) -> None:
    text = json.dumps(report, indent=2, sort_keys=True)
    if path == "-":
        print(text)
    else:
        with open(path, "w") as f:
            f.write(text + "\n")
        print(f"wrote {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on any violation or dispatch problem")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help='write the report as JSON ("-" for stdout)')
    ap.add_argument("--dataset", default="pex",
                    choices=["pex", "chain", "clique", "dbpedia_like"])
    ap.add_argument("--fixture", metavar="NAME", default=None,
                    help="audit a planted-violation fixture instead")
    ap.add_argument("--list-fns", action="store_true",
                    help="print the audited fn inventory and exit")
    args = ap.parse_args(argv)

    if args.fixture:
        return run_fixture(args.fixture, args.json)

    from . import audited_fn_labels, build_probe, run_report

    if args.list_fns:
        engine, state, _ = build_probe(args.dataset)
        for label in sorted(audited_fn_labels(engine, state)):
            print(label)
        return 0

    report = run_report(args.dataset)
    if args.json:
        _emit_json(report, args.json)

    n_fns = len(report["fns"])
    violations = report["violations"]
    problems = report["dispatch"]["problems"]
    print(
        f"audited {n_fns} fns on {report['dataset']!r} "
        f"(arena {report['arena_rows']}) with passes "
        f"{', '.join(report['passes'])}"
    )
    for v in violations:
        print(
            f"[{v['pass_name']}] {v['fn']}: {v['primitive']} at {v['path']}"
            f" — {v['detail']}"
        )
    for p in problems:
        print(f"[DispatchAuditor] {p}")
    print(
        f"{len(violations)} violation(s), {len(problems)} dispatch "
        f"problem(s); {report['dispatch']['total']} runtime dispatches "
        "observed"
    )
    if args.check and (violations or problems):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
