"""Maintenance worker thread: updates off the query path.

:class:`MaintenanceWorker` is the threaded half of the serving scheduler
(:mod:`repro.serve.triple_store`).  It owns NO state of its own beyond the
thread and two flags — the update queue, the in-flight ticket and the
engine state all live on the :class:`~repro.serve.triple_store.TripleStore`
— and consumes the store's update queue under the store's condition
variable, running each admitted operation's maintenance phases to its epoch
barrier (capacity retries included) exactly like the cooperative
``step()`` loop does, just on this thread instead of the caller's.

Why this is safe (the thread-safety argument, docs/serving.md):

  * the worker is the ONLY thread that touches the live
    :class:`~repro.core.engine_jax.EngineState` — readers never do;
  * readers see the store exclusively through the *published*
    :class:`~repro.core.engine_jax.StoreSnapshot`, whose publication is a
    single reference assignment (atomic under the GIL) at the epoch
    barrier; snapshots are immutable after publication and the swap
    retires the previous buffers by dropping the reference, so a lagging
    reader holding an old snapshot keeps it alive — buffers are never
    donated or mutated out from under anyone;
  * admission (queue appends) and ``pending()`` take the store's lock;
  * the engine's dispatch counter keeps its phase tag thread-local
    (:class:`repro.core.stats.DispatchCounter`), so the worker's
    maintenance phases and concurrent readers' ``"query"`` dispatches
    cannot mis-attribute each other.

A failed update parks its exception on :attr:`error` (and the ticket's
status becomes ``"failed"``); the store's ``drain()`` re-raises it on the
caller's thread rather than letting it die silently on this one.
"""

from __future__ import annotations

import threading

__all__ = ["MaintenanceWorker"]


class MaintenanceWorker:
    """Daemon thread draining a TripleStore's update queue to epoch barriers."""

    def __init__(self, store, name: str = "repro-maintenance") -> None:
        self._store = store
        self._stop = False
        self._busy = False
        self.error: BaseException | None = None
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    @property
    def busy(self) -> bool:
        """True while an update is being advanced (popped but not finished)."""
        return self._busy

    def _loop(self) -> None:
        store = self._store
        cond = store._work
        while True:
            with cond:
                while not store._uqueue and not self._stop:
                    cond.wait()
                if self._stop and not store._uqueue:
                    return
                ticket = store._uqueue.popleft()
                self._busy = True
            try:
                store._run_one_update(ticket)
            except BaseException as e:  # surface on the caller's thread
                ticket.status = "failed"
                self.error = e
            finally:
                with cond:
                    self._busy = False
                    cond.notify_all()

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until the update queue is empty and no update is in flight.

        Returns False on timeout.  Queries are NOT waited on — they drain
        on reader threads against the published snapshot.
        """
        with self._store._work:
            return self._store._work.wait_for(
                lambda: not self._store._uqueue and not self._busy, timeout
            )

    def check(self) -> None:
        """Re-raise (once) an exception a background update died with."""
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def stop(self, timeout: float | None = 30.0) -> None:
        """Graceful shutdown: finish queued updates, then exit the thread."""
        with self._store._work:
            self._stop = True
            self._store._work.notify_all()
        self._thread.join(timeout)
