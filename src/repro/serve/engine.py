"""Batched LM serving with continuous batching over a static KV arena.

Production decode servers keep a fixed (B, T) KV cache arena and swap
finished sequences for queued requests between decode steps — the jitted
``decode_step`` sees only static shapes while the scheduler runs on host:

  * admit: a free slot gets the next queued request; its prompt is prefilled
    into the slot's cache rows (one-slot prefill, right-padded),
  * decode: one fused step advances every active slot by a token,
  * evict: slots hitting EOS or ``max_new`` are drained and freed.

Per-slot positions make the single shared ``pos`` counter of naive batching
unnecessary — sequences of different lengths coexist (the attention mask is
per-slot: cache entries at >= slot_pos are masked out).

This is the ``serve_step`` that the decode dry-run cells lower; here it also
runs end-to-end on CPU with reduced configs (tests/test_serve.py,
examples/serve_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as lm
from repro.models.layers import DTYPE, rope_angles
from repro.models.transformer import LMConfig, _layer, logits_of, rms_norm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list  # token ids
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def decode_step_multipos(params, cfg: LMConfig, cache, tokens, positions):
    """One decode step with PER-SLOT positions.

    tokens (B,) int32; positions (B,) int32 current length of each slot.
    Returns (logits (B,V), new cache).
    """
    b = tokens.shape[0]
    x = params["embed"].astype(DTYPE)[tokens][:, None, :]
    cos, sin = rope_angles(positions.astype(jnp.float32), cfg.d_head, cfg.rope_theta)
    cos, sin = cos[:, None, :], sin[:, None, :]  # (B,1,half)

    def body(x, scanned):
        lp, kc, vc = scanned
        out, _, (kc, vc) = _layer(
            cfg, x, lp, cos, sin, q_offset=positions, k_cache=kc, v_cache=vc
        )
        return out, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_of(params, hidden)[:, 0, :]
    return logits, {"k": ks, "v": vs}


class ServeEngine:
    def __init__(self, params, cfg: LMConfig, n_slots: int, max_len: int,
                 sample: Callable | None = None, eos_id: int = 1):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id = eos_id
        self.sample = sample or (lambda logits: jnp.argmax(logits, -1).astype(jnp.int32))
        self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.positions = np.zeros(n_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.last_tok = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step_multipos(p, cfg, c, t, pos)
        )
        # one-slot prefill reused across admissions (padded to max_len? no —
        # prompt lengths vary; we prefill token-by-token through the decode
        # path for simplicity at small scale, or batched via prefill() once)
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, cfg, toks)
        )

    # -- scheduler ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray([req.prompt], jnp.int32)
                logits, cache1 = self._prefill(self.params, toks)
                plen = len(req.prompt)
                # write the slot's prefilled KV rows into the arena
                for key in ("k", "v"):
                    arena = self.cache[key]
                    rows = cache1[key][:, 0]  # (L, plen, KV, Dh)
                    arena = jax.lax.dynamic_update_slice(
                        arena, rows[:, None], (0, slot, 0, 0, 0)
                    )
                    self.cache[key] = arena
                tok = int(np.asarray(self.sample(logits[0, -1])))
                self.slot_req[slot] = req
                self.positions[slot] = plen
                self.last_tok[slot] = tok
                req.out.append(tok)

    def _evict(self):
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            hit_eos = req.out and req.out[-1] == self.eos_id
            full = len(req.out) >= req.max_new or self.positions[slot] >= self.max_len - 1
            if hit_eos or full:
                req.done = True
                self.finished.append(req)
                self.slot_req[slot] = None
                self.positions[slot] = 0

    def step(self):
        """One scheduler tick: admit -> fused decode -> evict."""
        self._admit()
        self._evict()  # a prompt whose first sampled token is EOS is done
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if active:
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self.last_tok), jnp.asarray(self.positions),
            )
            toks = np.asarray(self.sample(logits))
            for slot in active:
                self.positions[slot] += 1
                self.last_tok[slot] = toks[slot]
                self.slot_req[slot].out.append(int(toks[slot]))
        self._evict()
        return len(active)

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
