"""Live SPARQL serving over incremental maintenance (epoch-snapshot reads).

The paper's payoff is that rewriting keeps the materialisation small enough
to *query* quickly; this module is where that payoff is served.  A
:class:`TripleStore` owns a device-resident materialised
:class:`~repro.core.engine_jax.EngineState` and admits two workloads against
it: add/delete batches (maintained through the sharded incremental rounds of
:mod:`repro.core.incremental_spmd`) and SPARQL queries (answered against
published snapshots — batched on device by
:mod:`repro.sparql.batched`, scalar on host by
:mod:`repro.sparql.executor`).

**Epoch-snapshot consistency** (the serving contract, docs/serving.md):
every query is answered against the fixpoint of some *completed* maintenance
epoch — never a mid-round state where tombstoned facts await rederivation or
a clique split is half-applied — and its answers are expanded through that
epoch's rho (the paper's rewriting contract: match over representatives,
expand answers to cliques).  Concretely:

  * maintenance operations advance through the resumable *phases* of
    :func:`~repro.core.incremental_spmd.spmd_add_phases` /
    :func:`~repro.core.incremental_spmd.spmd_delete_phases`
    (adds: ``prepared``; deletes: ``seeded`` / ``wave``... /
    ``overdeleted`` / ``split`` / ``rederive``);
  * a :class:`~repro.core.engine_jax.StoreSnapshot` is published eagerly at
    every epoch barrier (:meth:`~repro.core.engine_jax.JaxEngine.publish_snapshot`):
    device-resident, double-buffered — publication is a reference swap plus
    an incremental :meth:`~repro.core.uf.FrozenRho.refreshed` rho refresh,
    and the build cost is charged to the barrier, never to the first read;
  * queries — whenever admitted, including between an overdelete wave and
    its rederivation — read the *published* snapshot, whose
    :class:`~repro.core.uf.FrozenRho` caches the clique expansion tables
    across all of the epoch's queries;
  * each answer carries ``epoch`` so callers (and the differential test
    harness in tests/test_serve_triple_store.py) can hold the store to the
    oracle: answer == evaluating the same query over the from-scratch
    materialisation of the explicit set as of that epoch.

**Two schedulers.**  The default (``threaded=False``) is the cooperative
deterministic loop — ``step()`` drains queued reads against the published
snapshot, then advances the in-flight update by exactly one phase — so
tests can construct any interleaving of queries racing maintenance rounds
and replay it exactly.  With ``threaded=True`` maintenance runs on a
:class:`~repro.serve.scheduler.MaintenanceWorker` thread instead:
admission and reads never block on maintenance (reads touch only the
published snapshot; the swap at the barrier is atomic), which is what the
epoch-snapshot discipline was buying all along — the cooperative mode
remains as the differential/test scheduler.  :class:`CapacityError`
retries (either mode) roll the state back to the pre-update snapshot, grow
the exhausted buffer, and restart the update's phases; readers keep being
served from the published snapshot throughout, so retries are invisible to
them.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import Counter, deque
from dataclasses import dataclass

import numpy as np

from repro.core.engine_jax import (
    CapacityError,
    EngineState,
    JaxEngine,
    StoreSnapshot,
    enable_x64,
)
from repro.core.incremental_spmd import spmd_add_phases, spmd_delete_phases
from repro.core.rules import Program
from repro.sparql.algebra import Query
from repro.sparql.batched import BatchedExecutor
from repro.sparql.executor import evaluate_at

from .scheduler import MaintenanceWorker

__all__ = ["TripleStore", "UpdateTicket", "QueryTicket"]


@dataclass
class UpdateTicket:
    """An admitted add/delete batch.

    ``epoch`` is assigned at the epoch barrier: the first snapshot whose
    fixpoint includes this batch.  ``wall_s`` is admission-to-barrier
    latency (in cooperative mode it includes any reads interleaved between
    the phases).  ``publish_ms`` is the snapshot publication cost paid at
    this ticket's barrier — reported separately so query latency columns
    measure queries (the BENCH_serve attribution fix).
    """

    uid: int
    op: str  # "add" | "delete"
    delta: np.ndarray
    status: str = "queued"  # queued | running | done | failed
    epoch: int | None = None
    wall_s: float = 0.0
    publish_ms: float = 0.0


@dataclass
class QueryTicket:
    """An admitted SPARQL query; ``epoch`` is the completed maintenance
    epoch whose snapshot the ``answer`` bag was evaluated against."""

    uid: int
    query: Query
    status: str = "queued"  # queued | done
    epoch: int | None = None
    answer: Counter | None = None
    wall_s: float = 0.0


class TripleStore:
    """A standing triple store serving SPARQL against a mutating store.

    Parameters
    ----------
    facts, program, dic:
        The explicit fact set, Datalog+sameAs program and dictionary —
        materialised to the base fixpoint (epoch 0) at construction.
    engine:
        A :class:`~repro.core.engine_jax.JaxEngine` (single-device or SPMD).
        When omitted one is sized to the workload the way bench_incremental
        does (~4x the explicit set, targeted retry growth absorbing
        misestimates).
    threaded:
        False (default): cooperative deterministic scheduler
        (``step``/``drain`` on the caller's thread).  True: maintenance
        runs on a background :class:`~repro.serve.scheduler.MaintenanceWorker`;
        ``step()`` is disabled, ``drain()`` waits for the worker while
        answering queued reads, and admission/reads never block on
        maintenance.
    batch_queries:
        Drain queued queries through the vmapped batched executor
        (:class:`repro.sparql.batched.BatchedExecutor`) when the published
        snapshot is device-resident; ``False`` forces the scalar host path
        (the differential baseline).  ``query_width`` / ``min_batch`` are
        the executor's knobs.

    The public surface is ``submit_update`` / ``submit_query`` /
    ``query_now`` (admission), ``step`` / ``drain`` (the scheduler),
    ``snapshot`` / ``epoch`` (the published read view) and ``close`` (stop
    the worker; also a context manager).
    """

    def __init__(
        self,
        facts: np.ndarray,
        program: Program,
        dic,
        engine: JaxEngine | None = None,
        max_rounds: int = 10_000,
        threaded: bool = False,
        batch_queries: bool = True,
        query_width: int = 4096,
        min_batch: int = 2,
        **engine_kw,
    ) -> None:
        facts = np.asarray(facts, np.int32).reshape(-1, 3)
        if engine is not None and engine_kw:
            raise TypeError(
                "engine_kw only applies when the store builds its own "
                f"engine; got an explicit engine AND {sorted(engine_kw)}"
            )
        if engine is None:
            cap = 1 << max(12, int(np.ceil(np.log2(max(4 * facts.shape[0], 2)))))
            kw = dict(
                capacity=cap, bind_cap=cap // 2, out_cap=cap // 2,
                rewrite_cap=cap // 4, seed_chunk=2048,
            )
            kw.update(engine_kw)
            engine = JaxEngine(dic.n_resources, **kw)
        self.engine = engine
        self.dic = dic
        self.max_rounds = max_rounds
        self.state: EngineState = engine.materialise_state(
            facts, program, max_rounds
        )
        self.inflight_phase: str | None = None
        self._uids = itertools.count()
        # deques: admission appends right, the scheduler pops left — O(1)
        # at both ends (the old list.pop(0) drain was O(n^2) per burst)
        self._uqueue: deque[UpdateTicket] = deque()
        self._qqueue: deque[QueryTicket] = deque()
        self._inflight: UpdateTicket | None = None
        self._gen = None
        self._snap: dict | None = None
        self._t_start = 0.0
        # one lock guards admission/queues/pending; the condition on it is
        # the worker's wakeup.  Published-snapshot reads are lock-free
        # (atomic reference load); publication swaps the reference at the
        # barrier.
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._batched = (
            BatchedExecutor(engine, width=query_width, min_batch=min_batch)
            if batch_queries else None
        )
        self.publish_ms: list[float] = []
        self._published: StoreSnapshot = self._publish()
        self.threaded = bool(threaded)
        self._worker = MaintenanceWorker(self) if threaded else None

    # -- read view -----------------------------------------------------------
    @property
    def epoch(self) -> int:
        """The published (last completed) maintenance epoch."""
        return self._published.epoch

    @property
    def snapshot(self) -> StoreSnapshot:
        """The published read view — eagerly built at each epoch barrier.

        Between updates it is the live state's fixpoint; while an update is
        mid-phase it is still the *previous* barrier's snapshot — NEVER a
        view of the live mid-round arrays.  Safe to read from any thread:
        publication replaces the reference, it never mutates a snapshot.
        """
        return self._published

    @property
    def inflight(self) -> UpdateTicket | None:
        return self._inflight

    @property
    def dispatch_counts(self) -> dict:
        """Runtime compiled-call dispatch totals of the serving engine.

        ``by_phase`` attributes dispatches to the maintenance phase that
        issued them (the generators tag ``engine.dispatches``; scheduler
        retries restart the generator, so retried phases count twice — the
        real cost).  Snapshot publication dispatches under ``"publish"``
        and batched query execution under ``"query"``.  The static half
        lives in :func:`repro.core.incremental_spmd.static_dispatch_profile`.
        """
        d = self.engine.dispatches
        return {
            "total": d.total,
            "by_family": dict(d.by_family),
            "by_phase": {
                f"{ph}/{fam}": n
                for (ph, fam), n in d.by_phase.items()
                if ph is not None
            },
            "compiles_by_family": dict(d.compiles),
        }

    def audit(self) -> list[str]:
        """Cross-check this store's observed dispatches against the static
        per-phase profile (the serving half of ``repro.analysis``'s
        DispatchAuditor).  Returns problem strings; empty means every
        (phase, family) dispatch pair was declared."""
        from repro.analysis import dispatch_crosscheck  # lazy: serving core

        return dispatch_crosscheck(
            self.engine.dispatches, self.state.base_program
        )

    def pending(self) -> int:
        """Queued + in-flight work items (0 means ``drain`` would be a no-op).

        Safe to call concurrently with the worker thread: the queues are
        read under the admission lock, and an update the worker has popped
        but not finished still counts via the worker's busy flag.
        """
        with self._lock:
            n = len(self._uqueue) + len(self._qqueue)
            busy = self._worker is not None and self._worker.busy
            if self._inflight is not None or busy:
                n += 1
            return n

    # -- admission -----------------------------------------------------------
    def submit_update(self, op: str, delta) -> UpdateTicket:
        if op == "del":
            op = "delete"
        if op not in ("add", "delete"):
            raise ValueError(f"unknown update op {op!r}")
        t = UpdateTicket(
            next(self._uids), op, np.asarray(delta, np.int32).reshape(-1, 3)
        )
        with self._work:
            self._uqueue.append(t)
            self._work.notify()
        return t

    def submit_query(self, q: Query) -> QueryTicket:
        t = QueryTicket(next(self._uids), q)
        with self._lock:
            self._qqueue.append(t)
        return t

    def query_now(self, q: Query) -> QueryTicket:
        """Admit and answer immediately against the published snapshot.

        Safe at any point — including while an update is mid-phase on the
        worker thread — because reads never touch the live state.
        """
        t = self.submit_query(q)
        self._drain_queries()
        return t

    # -- scheduler -----------------------------------------------------------
    def step(self) -> bool:
        """One cooperative scheduler tick: answer queued reads at the
        published snapshot, then advance the in-flight maintenance operation
        by one phase (admitting the next queued update if none is in
        flight).  Returns True iff any work was done.  Disabled in threaded
        mode — the worker owns maintenance there."""
        if self.threaded:
            raise RuntimeError(
                "step() is the cooperative scheduler; this store runs "
                "threaded=True — use drain() / query_now()"
            )
        progressed = bool(self._qqueue)
        self._drain_queries()
        if self._inflight is None and self._uqueue:
            with self._lock:
                t = self._uqueue.popleft()
            self._begin(t)
        if self._inflight is not None:
            self._advance()
            progressed = True
        return progressed

    def drain(self, max_ticks: int = 100_000) -> "TripleStore":
        """Run until all queues are empty and no update is in flight; the
        published snapshot is then the newest epoch's.  Cooperative mode
        ticks the scheduler; threaded mode answers queued reads on THIS
        thread while waiting for the worker to reach its barrier(s), and
        re-raises any exception a background update died with."""
        if self.threaded:
            ticks = 0
            while True:
                self._drain_queries()
                self._worker.check()
                if self._worker.wait_idle(timeout=0.05):
                    self._drain_queries()
                    self._worker.check()
                    if not self.pending():
                        return self
                ticks += 1
                if ticks > max_ticks:
                    raise RuntimeError("drain did not converge")
        ticks = 0
        while self.pending():
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("drain did not converge")
        return self

    def close(self) -> None:
        """Stop the worker thread (threaded mode); idempotent."""
        if self._worker is not None:
            self._worker.stop()
            self._worker.check()
            self._worker = None
            self.threaded = False

    def __enter__(self) -> "TripleStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals -----------------------------------------------------------
    def _publish(self) -> StoreSnapshot:
        """Publish the current barrier's snapshot (timed, double-buffered).

        The host ``triples`` copy is materialised here too: scalar-fallback
        readers (non-batchable shapes, singleton drains) must not pay a
        lazy device->host copy on the first read after a barrier — ALL
        snapshot build cost belongs to the barrier (``publish_ms``), on
        every query path.
        """
        t0 = time.perf_counter()
        snap = self.engine.publish_snapshot(
            self.state, prev=getattr(self, "_published", None)
        )
        snap.triples  # noqa: B018  — eager host copy, charged to the barrier
        snap.rho.members, snap.rho.sizes, snap.rho._csr()  # expansion tables too
        ms = (time.perf_counter() - t0) * 1e3
        self.publish_ms.append(ms)
        return snap

    def _drain_queries(self) -> None:
        """Answer every queued query against one consistent snapshot.

        Grabs the whole queue in one locked pop, then evaluates the batch
        — vmapped by shape groups when the snapshot is device-resident —
        entirely outside the lock.  Concurrent callers pop disjoint
        batches, so this is safe from any thread.
        """
        while True:
            with self._lock:
                batch = list(self._qqueue)
                self._qqueue.clear()
            if not batch:
                return
            snap = self.snapshot
            if self._batched is not None:
                t0 = time.perf_counter()
                res = self._batched.run(
                    [t.query for t in batch], snap, self.dic
                )
                per = (time.perf_counter() - t0) / len(batch)
                for t, (ans, ep) in zip(batch, res):
                    t.answer, t.epoch = ans, ep
                    t.wall_s, t.status = per, "done"
            else:
                for t in batch:
                    t0 = time.perf_counter()
                    t.answer, t.epoch = evaluate_at(t.query, snap, self.dic)
                    t.wall_s = time.perf_counter() - t0
                    t.status = "done"

    def _run_one_update(self, t: UpdateTicket) -> None:
        """Begin an admitted update and advance it to its epoch barrier —
        the worker thread's unit of work (threaded mode only).

        A failed update must not wedge the scheduler: the state rolls back
        to the pre-update snapshot (readers were on the published snapshot
        all along, so nothing they saw ever included the aborted work) and
        the in-flight slot clears before the exception is parked for the
        caller's ``drain()``.
        """
        try:
            self._begin(t)
            while self._inflight is not None:
                self._advance()
        except BaseException:
            if self._snap is not None:
                self.engine._restore(self.state, self._snap)
            self._inflight, self._gen, self._snap = None, None, None
            self.inflight_phase = None
            raise

    def _make_gen(self, t: UpdateTicket):
        fn = spmd_add_phases if t.op == "add" else spmd_delete_phases
        return fn(self.engine, self.state, t.delta, self.max_rounds)

    def _begin(self, t: UpdateTicket) -> None:
        self._inflight = t
        t.status = "running"
        self._t_start = time.perf_counter()
        self.engine._maybe_reset_fallback(self.state)
        self._snap = self.engine._snapshot(self.state)
        self._gen = self._make_gen(t)
        self.inflight_phase = "admitted"

    def _advance(self) -> None:
        """Advance the in-flight operation by one phase, with capacity retry.

        On :class:`CapacityError` the state rolls back to the pre-update
        snapshot, exactly the exhausted capacity doubles (arena re-layout if
        the store itself grew), and the operation restarts from its first
        phase in the same tick — the published snapshot, and hence every
        reader, is unaffected.

        ``stats.wall_seconds`` accumulates only the time spent in here
        (maintenance phases + retries), matching its meaning on the direct
        engine API — reads interleaved between phases are not charged.
        """
        eng = self.engine
        t0 = time.perf_counter()
        try:
            while True:
                try:
                    eng._set_update_buffers(True)
                    with enable_x64():
                        self.inflight_phase = next(self._gen)
                    return
                except StopIteration:
                    self._finish()
                    return
                except CapacityError as e:
                    eng._recover_capacity(self.state, self._snap, e)
                    self._snap = eng._snapshot(self.state)
                    self._gen = self._make_gen(self._inflight)
                    self.inflight_phase = "admitted"
        finally:
            self.state.stats.wall_seconds += time.perf_counter() - t0

    def _finish(self) -> None:
        """Cross the epoch barrier and publish the new epoch's snapshot.

        Publication happens HERE, eagerly — a buffer swap visible to
        readers the moment the barrier completes — so the build cost lands
        on the update that caused it (``ticket.publish_ms``), never on the
        first unlucky read (the BENCH_serve ``busy_over_idle`` attribution
        fix).
        """
        t = self._inflight
        self.engine._barrier(self.state)
        self._published = self._publish()
        t.publish_ms = self.publish_ms[-1]
        t.epoch = self.state.update_epoch
        t.status = "done"
        t.wall_s = time.perf_counter() - self._t_start
        self._inflight, self._gen, self._snap = None, None, None
        self.inflight_phase = None
