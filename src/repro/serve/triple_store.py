"""Live SPARQL serving over incremental maintenance (epoch-snapshot reads).

The paper's payoff is that rewriting keeps the materialisation small enough
to *query* quickly; this module is where that payoff is served.  A
:class:`TripleStore` owns a device-resident materialised
:class:`~repro.core.engine_jax.EngineState` and admits two workloads against
it: add/delete batches (maintained through the sharded incremental rounds of
:mod:`repro.core.incremental_spmd`) and SPARQL queries (answered by
:mod:`repro.sparql.executor`).

**Epoch-snapshot consistency** (the serving contract, docs/serving.md):
every query is answered against the fixpoint of some *completed* maintenance
epoch — never a mid-round state where tombstoned facts await rederivation or
a clique split is half-applied — and its answers are expanded through that
epoch's rho (the paper's rewriting contract: match over representatives,
expand answers to cliques).  Concretely:

  * maintenance operations advance through the resumable *phases* of
    :func:`~repro.core.incremental_spmd.spmd_add_phases` /
    :func:`~repro.core.incremental_spmd.spmd_delete_phases`
    (adds: ``prepared``; deletes: ``seeded`` / ``wave``... /
    ``overdeleted`` / ``split`` / ``rederive``), one phase per scheduler
    tick;
  * a :class:`~repro.core.engine_jax.StoreSnapshot` is published only at the
    epoch barrier (operation fixpoint reached) — built lazily on first read
    (unread epochs cost no host copy), from the in-flight operation's
    pre-update rollback snapshot when a read lands mid-phase;
  * queries — whenever admitted, including between an overdelete wave and
    its rederivation — read the *published* snapshot, whose
    :class:`~repro.core.uf.FrozenRho` caches the clique expansion tables
    across all of the epoch's queries;
  * each answer carries ``epoch`` so callers (and the differential test
    harness in tests/test_serve_triple_store.py) can hold the store to the
    oracle: answer == evaluating the same query over the from-scratch
    materialisation of the explicit set as of that epoch.

The scheduler is cooperative and deterministic — ``step()`` drains queued
reads against the published snapshot, then advances the in-flight update by
exactly one phase — so tests can construct any interleaving of queries
racing maintenance rounds and replay it exactly.  :class:`CapacityError`
retries roll the state back to the pre-update snapshot, grow the exhausted
buffer, and restart the update's phases; readers keep being served from the
published snapshot throughout, so retries are invisible to them.
"""

from __future__ import annotations

import itertools
import time
from collections import Counter
from dataclasses import dataclass

import numpy as np

from repro.core.engine_jax import (
    CapacityError,
    EngineState,
    JaxEngine,
    StoreSnapshot,
    enable_x64,
)
from repro.core.incremental_spmd import spmd_add_phases, spmd_delete_phases
from repro.core.rules import Program
from repro.sparql.algebra import Query
from repro.sparql.executor import evaluate_at

__all__ = ["TripleStore", "UpdateTicket", "QueryTicket"]


@dataclass
class UpdateTicket:
    """An admitted add/delete batch.

    ``epoch`` is assigned at the epoch barrier: the first snapshot whose
    fixpoint includes this batch.  ``wall_s`` is admission-to-barrier
    latency (it includes any reads interleaved between the phases).
    """

    uid: int
    op: str  # "add" | "delete"
    delta: np.ndarray
    status: str = "queued"  # queued | running | done
    epoch: int | None = None
    wall_s: float = 0.0


@dataclass
class QueryTicket:
    """An admitted SPARQL query; ``epoch`` is the completed maintenance
    epoch whose snapshot the ``answer`` bag was evaluated against."""

    uid: int
    query: Query
    status: str = "queued"  # queued | done
    epoch: int | None = None
    answer: Counter | None = None
    wall_s: float = 0.0


class TripleStore:
    """A standing triple store serving SPARQL against a mutating store.

    Parameters
    ----------
    facts, program, dic:
        The explicit fact set, Datalog+sameAs program and dictionary —
        materialised to the base fixpoint (epoch 0) at construction.
    engine:
        A :class:`~repro.core.engine_jax.JaxEngine` (single-device or SPMD).
        When omitted one is sized to the workload the way bench_incremental
        does (~4x the explicit set, targeted retry growth absorbing
        misestimates).

    The public surface is ``submit_update`` / ``submit_query`` /
    ``query_now`` (admission), ``step`` / ``drain`` (the scheduler) and
    ``snapshot`` / ``epoch`` (the published read view).
    """

    def __init__(
        self,
        facts: np.ndarray,
        program: Program,
        dic,
        engine: JaxEngine | None = None,
        max_rounds: int = 10_000,
        **engine_kw,
    ) -> None:
        facts = np.asarray(facts, np.int32).reshape(-1, 3)
        if engine is not None and engine_kw:
            raise TypeError(
                "engine_kw only applies when the store builds its own "
                f"engine; got an explicit engine AND {sorted(engine_kw)}"
            )
        if engine is None:
            cap = 1 << max(12, int(np.ceil(np.log2(max(4 * facts.shape[0], 2)))))
            kw = dict(
                capacity=cap, bind_cap=cap // 2, out_cap=cap // 2,
                rewrite_cap=cap // 4, seed_chunk=2048,
            )
            kw.update(engine_kw)
            engine = JaxEngine(dic.n_resources, **kw)
        self.engine = engine
        self.dic = dic
        self.max_rounds = max_rounds
        self.state: EngineState = engine.materialise_state(
            facts, program, max_rounds
        )
        self.inflight_phase: str | None = None
        self._uids = itertools.count()
        self._uqueue: list[UpdateTicket] = []
        self._qqueue: list[QueryTicket] = []
        self._inflight: UpdateTicket | None = None
        self._gen = None
        self._snap: dict | None = None
        self._t_start = 0.0
        self._published: StoreSnapshot | None = None  # built on first read

    # -- read view -----------------------------------------------------------
    @property
    def epoch(self) -> int:
        """The published (last completed) maintenance epoch."""
        return self.state.update_epoch

    @property
    def snapshot(self) -> StoreSnapshot:
        """The published read view, built lazily so unread epochs are free.

        Between updates the view comes from the live state (which is at a
        barrier); while an update is mid-phase it is built from the
        operation's pre-update rollback snapshot — also a barrier state —
        NEVER from the live mid-round arrays.
        """
        if self._published is None:
            if self._inflight is None:
                self._published = self.engine.read_snapshot(self.state)
            else:
                s = self._snap
                self._published = self.engine.snapshot_arrays(
                    s["spo"], s["epoch"], s["marked"], s["rep"],
                    s["update_epoch"],
                    sort_perm=s["sort_perm"], sorted_keys=s["sorted_keys"],
                    index_dirty=s["index_dirty"],
                )
        return self._published

    @property
    def inflight(self) -> UpdateTicket | None:
        return self._inflight

    @property
    def dispatch_counts(self) -> dict:
        """Runtime compiled-call dispatch totals of the serving engine.

        ``by_phase`` attributes dispatches to the maintenance phase that
        issued them (the generators tag ``engine.dispatches``; scheduler
        retries restart the generator, so retried phases count twice — the
        real cost).  The static half lives in
        :func:`repro.core.incremental_spmd.static_dispatch_profile`.
        """
        d = self.engine.dispatches
        return {
            "total": d.total,
            "by_family": dict(d.by_family),
            "by_phase": {
                f"{ph}/{fam}": n
                for (ph, fam), n in d.by_phase.items()
                if ph is not None
            },
            "compiles_by_family": dict(d.compiles),
        }

    def audit(self) -> list[str]:
        """Cross-check this store's observed dispatches against the static
        per-phase profile (the serving half of ``repro.analysis``'s
        DispatchAuditor).  Returns problem strings; empty means every
        (phase, family) dispatch pair was declared."""
        from repro.analysis import dispatch_crosscheck  # lazy: serving core

        return dispatch_crosscheck(
            self.engine.dispatches, self.state.base_program
        )

    def pending(self) -> int:
        """Queued + in-flight work items (0 means ``drain`` would be a no-op)."""
        return (
            len(self._uqueue) + len(self._qqueue)
            + (1 if self._inflight is not None else 0)
        )

    # -- admission -----------------------------------------------------------
    def submit_update(self, op: str, delta) -> UpdateTicket:
        if op == "del":
            op = "delete"
        if op not in ("add", "delete"):
            raise ValueError(f"unknown update op {op!r}")
        t = UpdateTicket(
            next(self._uids), op, np.asarray(delta, np.int32).reshape(-1, 3)
        )
        self._uqueue.append(t)
        return t

    def submit_query(self, q: Query) -> QueryTicket:
        t = QueryTicket(next(self._uids), q)
        self._qqueue.append(t)
        return t

    def query_now(self, q: Query) -> QueryTicket:
        """Admit and answer immediately against the published snapshot.

        Safe at any point — including while an update is mid-phase — because
        reads never touch the live state.
        """
        t = self.submit_query(q)
        self._drain_queries()
        return t

    # -- scheduler -----------------------------------------------------------
    def step(self) -> bool:
        """One scheduler tick: answer queued reads at the published snapshot,
        then advance the in-flight maintenance operation by one phase
        (admitting the next queued update if none is in flight).  Returns
        True iff any work was done."""
        progressed = bool(self._qqueue)
        self._drain_queries()
        if self._inflight is None and self._uqueue:
            self._begin(self._uqueue.pop(0))
        if self._inflight is not None:
            self._advance()
            progressed = True
        return progressed

    def drain(self, max_ticks: int = 100_000) -> "TripleStore":
        """Run scheduler ticks until all queues are empty and no update is in
        flight; the published snapshot is then the newest epoch's."""
        ticks = 0
        while self.pending():
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("drain did not converge")
        return self

    # -- internals -----------------------------------------------------------
    def _drain_queries(self) -> None:
        while self._qqueue:
            t = self._qqueue.pop(0)
            t0 = time.perf_counter()
            t.answer, t.epoch = evaluate_at(t.query, self.snapshot, self.dic)
            t.wall_s = time.perf_counter() - t0
            t.status = "done"

    def _make_gen(self, t: UpdateTicket):
        fn = spmd_add_phases if t.op == "add" else spmd_delete_phases
        return fn(self.engine, self.state, t.delta, self.max_rounds)

    def _begin(self, t: UpdateTicket) -> None:
        self._inflight = t
        t.status = "running"
        self._t_start = time.perf_counter()
        self.engine._maybe_reset_fallback(self.state)
        self._snap = self.engine._snapshot(self.state)
        self._gen = self._make_gen(t)
        self.inflight_phase = "admitted"

    def _advance(self) -> None:
        """Advance the in-flight operation by one phase, with capacity retry.

        On :class:`CapacityError` the state rolls back to the pre-update
        snapshot, exactly the exhausted capacity doubles (arena re-layout if
        the store itself grew), and the operation restarts from its first
        phase in the same tick — the published snapshot, and hence every
        reader, is unaffected.

        ``stats.wall_seconds`` accumulates only the time spent in here
        (maintenance phases + retries), matching its meaning on the direct
        engine API — reads interleaved between phases are not charged.
        """
        eng = self.engine
        t0 = time.perf_counter()
        try:
            while True:
                try:
                    eng._set_update_buffers(True)
                    with enable_x64():
                        self.inflight_phase = next(self._gen)
                    return
                except StopIteration:
                    self._finish()
                    return
                except CapacityError as e:
                    eng._recover_capacity(self.state, self._snap, e)
                    self._snap = eng._snapshot(self.state)
                    self._gen = self._make_gen(self._inflight)
                    self.inflight_phase = "admitted"
        finally:
            self.state.stats.wall_seconds += time.perf_counter() - t0

    def _finish(self) -> None:
        """Cross the epoch barrier; the next read publishes the new view."""
        t = self._inflight
        self.engine._barrier(self.state)
        self._published = None
        t.epoch = self.state.update_epoch
        t.status = "done"
        t.wall_s = time.perf_counter() - self._t_start
        self._inflight, self._gen, self._snap = None, None, None
        self.inflight_phase = None
