from .engine import Request, ServeEngine
from .triple_store import QueryTicket, TripleStore, UpdateTicket

__all__ = ["Request", "ServeEngine", "TripleStore", "UpdateTicket", "QueryTicket"]
