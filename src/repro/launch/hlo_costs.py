"""Loop-aware cost analysis over compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
lowered with ``lax.scan`` (scan-over-layers, chunked attention, grad accum)
under-reports FLOPs/bytes by the trip count — useless for a roofline.  This
module re-derives the three roofline inputs from the HLO text with loop
multipliers:

  * every computation gets a multiplier = product of trip counts of the
    ``while`` loops enclosing it (trip counts parsed from loop conditions —
    exact for ``scan``/``fori_loop``, which compare against a constant),
  * FLOPs: ``dot`` = 2 * prod(result dims) * prod(lhs contracting dims);
    elementwise arithmetic = result elements; transcendentals counted apart,
  * bytes: per instruction, operands + result (fusions count only their
    boundary, matching XLA's fusion cost model; pure-layout ops are free),
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, times the multiplier.

Everything is per-device (the module is the per-device SPMD program).
Validated against ``cost_analysis()`` on loop-free modules and against an
unrolled-vs-scanned pair (tests/test_hlo_costs.py).

Both :func:`analyse_hlo` and the XLA baseline accessor
:func:`xla_cost_analysis` (re-exported from :mod:`repro.compat`) return a
flat ``dict`` — jax 0.4.x wraps ``Compiled.cost_analysis()`` in a
single-element list, which the compat shim unwraps.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.compat import xla_cost_analysis

__all__ = ["analyse_hlo", "parse_module", "xla_cost_analysis"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9-]*)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->.*\{\s*$")

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
    "call", "copy-start", "copy-done", "all-gather-done", "all-reduce-done",
    "collective-permute-done", "opt-barrier",
}
_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "floor", "ceil", "round-nearest-afz", "sign",
}
_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "logistic",
    "sine", "cosine", "expm1", "log1p", "erf", "atan2", "cbrt",
}


def _shape_elems_bytes(shape_text: str) -> tuple[float, float]:
    """Total (elements, bytes) over all array shapes in ``shape_text``."""
    elems = nbytes = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class _Instr:
    name: str
    shape_text: str
    opcode: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: list
    by_name: dict


def parse_module(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("->" in line):
            cur = _Comp(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_text, opcode, rest = m.groups()
        ins = _Instr(name, shape_text, opcode, rest)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


def _operand_names(rest: str) -> list[str]:
    """Names referenced in the operand list (up to the closing paren)."""
    depth, i = 1, 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return re.findall(r"%([\w.-]+)", rest[:i])


def _attr_comp_refs(rest: str) -> dict[str, list[str]]:
    """computation-valued attributes after the operand list."""
    refs = defaultdict(list)
    for key, val in re.findall(r"(\w+)=%([\w.-]+)", rest):
        refs[key].append(val)
    for m in re.finditer(r"(\w+)=\{([^}]*)\}", rest):
        key, body = m.groups()
        names = re.findall(r"%([\w.-]+)", body)
        if names:
            refs[key].extend(names)
    return refs


def _trip_count(cond: _Comp) -> int:
    """Largest integer constant in the loop condition; exact for scan."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: _Instr, comp: _Comp) -> float:
    out_elems, _ = _shape_elems_bytes(ins.shape_text)
    ops = _operand_names(ins.rest)
    k = 1.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if m and ops:
        lhs = comp.by_name.get(ops[0])
        if lhs is not None:
            dims_m = _SHAPE_RE.search(lhs.shape_text)
            if dims_m:
                lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
    return 2.0 * out_elems * k


# Ops whose operands/results genuinely stream through HBM on TPU.  Plain
# elementwise chains (add/mul/convert/select/broadcast/...) fuse into these
# neighbours on TPU, so counting every CPU-HLO instruction (CPU barely
# fuses) inflates the memory term ~4x — found when the first roofline pass
# classified every cell as memory-bound (EXPERIMENTS.md §Roofline notes).
_BYTES_OPS = {
    "dot", "convolution", "fusion", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "sort", "reduce", "reduce-window", "custom-call",
    "concatenate", "pad", "transpose", "copy", "reverse", "cholesky",
    "triangular-solve", "fft", "rng", "select-and-scatter", "scatter-add",
}


def _operand_bytes_normalised(name: str, comp: _Comp) -> float:
    """Bytes of operand ``name``; if it is a convert/copy of a narrower
    value (XLA CPU promotes bf16 compute to f32), charge the narrower
    width — TPU reads the bf16 original."""
    ref = comp.by_name.get(name)
    if ref is None:
        return 0.0
    _, b = _shape_elems_bytes(ref.shape_text)
    if ref.opcode in ("convert", "copy", "bitcast"):
        srcs = _operand_names(ref.rest)
        if srcs:
            src = comp.by_name.get(srcs[0])
            if src is not None:
                _, sb = _shape_elems_bytes(src.shape_text)
                if 0 < sb < b:
                    return sb
    return b


def _instr_bytes(ins: _Instr, comp: _Comp) -> float:
    if ins.opcode in _SKIP_BYTES or ins.opcode in _COLLECTIVES:
        return 0.0
    if ins.opcode not in _BYTES_OPS:
        return 0.0  # assumed fused on TPU
    _, out_b = _shape_elems_bytes(ins.shape_text)
    ops = _operand_names(ins.rest)
    # indexed ops touch only the gathered/updated rows, not the whole
    # operand (a replicated 1.4 GiB embedding table must not count as
    # streamed per lookup — found on fm:serve_bulk):
    if ins.opcode in ("gather", "dynamic-slice"):
        idx_b = sum(
            b for op in ops[1:] for b in [_operand_bytes_normalised(op, comp)]
        )
        return 2.0 * out_b + idx_b  # rows read + result written + indices
    if ins.opcode == "dynamic-update-slice":
        # operands: (buffer, update, idx...) — buffer is aliased, not streamed
        upd_b = (
            _operand_bytes_normalised(ops[1], comp) if len(ops) > 1 else out_b
        )
        return 2.0 * upd_b
    if ins.opcode in ("scatter", "scatter-add", "select-and-scatter"):
        # operands: (buffer, indices, updates)
        upd_b = (
            _operand_bytes_normalised(ops[2], comp) if len(ops) > 2 else out_b
        )
        idx_b = _operand_bytes_normalised(ops[1], comp) if len(ops) > 1 else 0.0
        return 2.0 * upd_b + idx_b  # touched rows read-modify-write + indices
    in_b = 0.0
    for op in ops:
        in_b += _operand_bytes_normalised(op, comp)
    return out_b + in_b


def _collective_operand_bytes(ins: _Instr, comp: _Comp) -> float:
    total = 0.0
    for op in _operand_names(ins.rest):
        ref = comp.by_name.get(op)
        if ref is not None:
            _, b = _shape_elems_bytes(ref.shape_text)
            total += b
    if total == 0.0:  # operands carried inline shapes (older dumps)
        _, total = _shape_elems_bytes(ins.rest.split(")")[0])
    return total


def analyse_hlo(hlo: str, entry_hint: str | None = None) -> dict:
    comps = parse_module(hlo)
    if not comps:
        return {
            "flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
            "collective_bytes": 0.0, "collectives": {}, "max_multiplier": 1,
        }
    # entry = computation never referenced by others
    referenced = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for names in _attr_comp_refs(ins.rest).values():
                referenced.update(names)
    entries = [n for n in comps if n not in referenced]
    entry = entry_hint or (entries[-1] if entries else next(iter(comps)))

    flops = trans = nbytes = coll_bytes = 0.0
    coll_by_kind: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    max_mult = 1
    seen: set[tuple[str, int]] = set()

    def visit(comp_name: str, mult: float, count_bytes: bool):
        nonlocal flops, trans, nbytes, coll_bytes, max_mult
        comp = comps.get(comp_name)
        if comp is None:
            return
        key = (comp_name, int(mult))
        if key in seen:  # same computation at same multiplier (shared callees)
            return
        seen.add(key)
        max_mult = max(max_mult, int(mult))
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                flops += mult * _dot_flops(ins, comp)
            elif op == "convolution":
                flops += mult * 2.0 * _shape_elems_bytes(ins.shape_text)[0]
            elif op in _ELEMENTWISE_1FLOP:
                flops += mult * _shape_elems_bytes(ins.shape_text)[0]
            elif op in _TRANSCENDENTAL:
                trans += mult * _shape_elems_bytes(ins.shape_text)[0]
            if count_bytes:
                nbytes += mult * _instr_bytes(ins, comp)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                b = _collective_operand_bytes(ins, comp)
                coll_bytes += mult * b
                coll_by_kind[base]["count"] += int(mult)
                coll_by_kind[base]["bytes"] += mult * b
            # recurse into called computations
            refs = _attr_comp_refs(ins.rest)
            if op == "while":
                trip = 1
                for cname in refs.get("condition", []):
                    trip = max(trip, _trip_count(comps[cname]))
                for cname in refs.get("body", []):
                    visit(cname, mult * trip, count_bytes)
            elif op == "fusion":
                for cname in refs.get("calls", []):
                    visit(cname, mult, False)  # fusion bytes = boundary only
            elif op in ("call", "async-start", "custom-call"):
                for cname in refs.get("to_apply", []) + refs.get("called_computations", []):
                    visit(cname, mult, count_bytes)
            elif op == "conditional":
                branches = (
                    refs.get("branch_computations", [])
                    + refs.get("true_computation", [])
                    + refs.get("false_computation", [])
                )
                for cname in branches:
                    visit(cname, mult, count_bytes)
            # reduce/map/scatter/sort to_apply bodies are O(1)-per-element —
            # covered by the elementwise estimate of the parent op; skip.

    visit(entry, 1.0, True)
    return {
        "flops": flops,
        "bytes": nbytes,
        "transcendentals": trans,
        "collective_bytes": coll_bytes,
        "collectives": dict(coll_by_kind),
        "max_multiplier": max_mult,
        "entry": entry,
    }
