"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's third
term is derived here: we scan the partitioned module for every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction and sum the byte sizes of its *operands*
(per the assignment's metric).  The module is the per-device program, so all
numbers are bytes **per chip**; the roofline divides by per-link bandwidth.

Parsing is purely textual: an HLO instruction line looks like

  %all-reduce.5 = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %add.3), ...

Async pairs (``all-reduce-start``/``-done``) are counted once (on ``-start``).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# `<dtype>[d0,d1,...]` — layout `{...}` optional, dims may be empty (scalar).
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

# opcode position: `<result> = <shape-or-tuple> <opcode>(<operands...>)`
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)


def shape_bytes(dtype: str, dims_csv: str) -> float:
    n = 1
    if dims_csv:
        for d in dims_csv.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _operand_bytes(line: str, open_idx: int) -> float:
    """Sum shapes appearing in the operand list starting at ``open_idx``."""
    depth, i = 0, open_idx
    while i < len(line):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    operands = line[open_idx : i + 1]
    return sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(operands))


def collective_stats(hlo_text: str) -> dict:
    """Return {op_kind: {count, bytes}} + totals (bytes are per-device)."""
    by_kind: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        open_idx = line.index("(", m.start(1))
        nbytes = _operand_bytes(line, open_idx)
        by_kind[kind]["count"] += 1
        by_kind[kind]["bytes"] += nbytes
    total = sum(v["bytes"] for v in by_kind.values())
    count = sum(v["count"] for v in by_kind.values())
    return {"by_kind": dict(by_kind), "total_bytes": total, "total_count": count}


def duplicate_op_histogram(hlo_text: str, top: int = 12) -> list[tuple[str, int]]:
    """Count fusion-root op names — a remat/redundancy smell test (§Perf)."""
    counts: dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9-]*)\(", hlo_text):
        counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
