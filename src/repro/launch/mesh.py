"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must be
able to set ``XLA_FLAGS`` before the first jax call.

Axes:
  * ``pod``   — pure data parallelism across pods (DCN); gradients cross it
                once per step,
  * ``data``  — batch / edge / row sharding (ICI),
  * ``model`` — tensor/expert/vocab/embedding-row parallelism (ICI).

``axis_types_auto`` / ``make_mesh`` are re-exported from :mod:`repro.compat`
so callers that build their own meshes stay portable across the jax 0.4/0.6
``AxisType`` rename without feature-sniffing jax themselves.
"""

from __future__ import annotations

import jax

from repro.compat import axis_types_auto, make_mesh

__all__ = [
    "axis_types_auto", "make_mesh", "make_production_mesh",
    "make_engine_mesh", "mesh_size", "data_axes", "model_axis",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_engine_mesh(n_devices: int | None = None, axis: str = "data"):
    """1-D mesh for the SPMD materialisation engine.

    ``n_devices`` smaller than the process's device count builds the mesh
    over a prefix of the devices — the device-count-invariance tests run
    1/2/4-shard engines inside one 4-device process this way.
    """
    devs = jax.devices()
    n = n_devices or len(devs)
    if n < len(devs):
        return make_mesh((n,), (axis,), devices=devs[:n])
    return make_mesh((n,), (axis,))


def mesh_size(mesh) -> int:
    """Total device count of a mesh (the engine's shard count on 1-D meshes)."""
    import numpy as np

    return int(np.prod(mesh.devices.shape))


def data_axes(mesh) -> tuple[str, ...]:
    """All batch-parallel axes of a mesh (pod + data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
