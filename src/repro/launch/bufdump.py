import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dump the largest HLO buffers of a dry-run cell (memory debugging aid).

Usage: PYTHONPATH=src python -m repro.launch.bufdump --arch X --shape Y [--mesh single]
"""

import argparse
import re


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=16)
    ap.add_argument("--min-mib", type=float, default=256.0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.workloads import build_cell

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    spec = get_arch(args.arch)
    wl = build_cell(spec, spec.shape(args.shape), mesh)
    with mesh:
        c = (
            jax.jit(wl.step, in_shardings=wl.in_shardings, out_shardings=wl.out_shardings)
            .lower(*wl.input_specs)
            .compile()
        )
    txt = c.as_text()
    db = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8}
    agg = {}
    for m in re.finditer(r"%([\w.-]+) = ([a-z0-9]+)\[([0-9,]*)\]\S* ([a-z][a-z0-9-]*)\(", txt):
        _, dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * db.get(dt, 4)
        if b >= args.min_mib * 2**20:
            key = f"{dt}[{dims}] {op}"
            cnt, _ = agg.get(key, (0, 0))
            agg[key] = (cnt + 1, b)
    ma = c.memory_analysis()
    print(f"peak = args {ma.argument_size_in_bytes/2**30:.2f} + temp "
          f"{ma.temp_size_in_bytes/2**30:.2f} + out {ma.output_size_in_bytes/2**30:.2f} GiB")
    for key, (cnt, b) in sorted(agg.items(), key=lambda kv: -kv[1][1])[: args.top]:
        print(f"{b/2**30:8.2f} GiB x{cnt:3d}  {key}")


if __name__ == "__main__":
    main()
