import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init); 512 placeholder CPU devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Per cell this driver:
  1. builds the ``Workload`` (step fn + ShapeDtypeStruct inputs + shardings),
  2. ``jax.jit(...).lower(...).compile()`` on the production mesh,
  3. prints ``compiled.memory_analysis()`` (proves the cell fits per-device)
     and ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline),
  4. extracts per-device collective bytes from the partitioned HLO
     (:mod:`repro.launch.hlo_stats`),
  5. writes ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both      # every cell, subprocesses
  python -m repro.launch.dryrun --all --jobs-file cells.txt

``--all`` runs each cell in a fresh subprocess: compile failures and memory
blow-ups stay isolated, and a crashed cell is recorded as status=error rather
than killing the sweep.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

HW = {  # TPU v5e targets (per chip)
    "peak_flops_bf16": 197e12,
    "hbm_bytes_per_s": 819e9,
    "ici_bytes_per_s_per_link": 50e9,
    "hbm_bytes": 16 * 1024**3,
}


def cell_filename(arch: str, shape: str, mesh: str) -> str:
    return f"{arch.replace('/', '_')}__{shape}__{mesh}.json"


def _bf16_dup_bytes(hlo: str) -> float:
    """Bytes of f32 dynamic-update-slice stacks that shadow a bf16 twin
    (CPU-only duplication; see run_cell)."""
    import re

    f32_stacks = set(
        re.findall(r"= f32\[([0-9,]+)\]\S* dynamic-update-slice\(", hlo)
    )
    bf16_dims = set(re.findall(r"\bbf16\[([0-9,]+)\]", hlo))
    total = 0.0
    for dims in f32_stacks & bf16_dims:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        total += 4 * n  # the f32 copy would not exist on TPU
    return total


def list_cells(mesh_kinds):
    """All (arch, shape, mesh) cells in assignment order (incl. skip cells)."""
    from repro.configs import all_archs, get_arch

    cells = []
    for arch in all_archs():
        spec = get_arch(arch)
        for shape in spec.shapes:
            for mk in mesh_kinds:
                cells.append((arch, shape.name, mk))
    return cells


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    """Lower+compile one cell in-process and write its JSON record."""
    import jax

    from repro.configs import get_arch
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_production_mesh
    from repro.launch.workloads import build_cell

    spec = get_arch(arch)
    shape = spec.shape(shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "status": "ok",
    }
    if shape.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = shape.skip
        _write(rec, out_dir)
        print(f"[dryrun] SKIP {arch}:{shape_name}:{mesh_kind} — {shape.skip}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    rec["n_devices"] = n_dev

    t0 = time.time()
    wl = build_cell(spec, shape, mesh)
    rec["build_s"] = round(time.time() - t0, 2)

    with mesh:
        t1 = time.time()
        jitted = jax.jit(
            wl.step, in_shardings=wl.in_shardings, out_shardings=wl.out_shardings,
            donate_argnums=wl.donate,
        )
        lowered = jitted.lower(*wl.input_specs)
        rec["lower_s"] = round(time.time() - t1, 2)
        t2 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t2, 2)

        ma = compiled.memory_analysis()
        print(f"[dryrun] {wl.name}:{mesh_kind} memory_analysis: {ma}")
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        }
        mem["peak_bytes"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"]
        )
        mem["fits_hbm"] = bool(mem["peak_bytes"] <= HW["hbm_bytes"])
        rec["memory"] = mem

        hlo = compiled.as_text()
        # XLA CPU's float normalisation keeps BOTH a bf16 and an f32 copy of
        # residual stacks (verified on a minimal scan+checkpoint repro); a
        # TPU lowering keeps only the bf16 one.  Estimate the TPU peak by
        # discounting f32 dus-stacks that have a same-dims bf16 twin.
        mem["tpu_est_bytes"] = mem["peak_bytes"] - _bf16_dup_bytes(hlo)
        mem["fits_hbm_tpu_est"] = bool(mem["tpu_est_bytes"] <= HW["hbm_bytes"])

        from repro.compat import xla_cost_analysis

        ca = xla_cost_analysis(compiled)
        print(
            f"[dryrun] {wl.name}:{mesh_kind} cost_analysis: "
            f"flops={ca.get('flops')} bytes={ca.get('bytes accessed')}"
        )
        # XLA's numbers count while bodies ONCE (wrong under scan-over-layers);
        # kept for reference only.  The roofline consumes the loop-aware pass.
        rec["cost_xla_raw"] = {
            "flops_per_dev": float(ca.get("flops", 0.0)),
            "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
            "transcendentals_per_dev": float(ca.get("transcendentals", 0.0)),
        }

        from repro.launch import hlo_costs

        rec["hlo_chars"] = len(hlo)
        la = hlo_costs.analyse_hlo(hlo)
        rec["cost"] = {
            "flops_per_dev": la["flops"],
            "bytes_per_dev": la["bytes"],
            "transcendentals_per_dev": la["transcendentals"],
            "loop_max_multiplier": la["max_multiplier"],
        }
        rec["collectives"] = {
            "by_kind": la["collectives"],
            "total_bytes": la["collective_bytes"],
            "total_count": sum(v["count"] for v in la["collectives"].values()),
        }
        rec["collectives_static"] = hlo_stats.collective_stats(hlo)
        rec["top_ops"] = hlo_stats.duplicate_op_histogram(hlo)

    rec["model_flops_global"] = wl.model_flops
    rec["notes"] = wl.notes
    _write(rec, out_dir)
    tot_c = rec["collectives"]["total_bytes"]
    print(
        f"[dryrun] OK {wl.name}:{mesh_kind} devs={n_dev} "
        f"compile={rec['compile_s']}s flops/dev={rec['cost']['flops_per_dev']:.3e} "
        f"coll_bytes/dev={tot_c:.3e} peak_mem={mem['peak_bytes']/2**30:.2f}GiB "
        f"fits={mem['fits_hbm']}"
    )
    return rec


def _write(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_filename(rec["arch"], rec["shape"], rec["mesh"]))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)


def run_all(mesh_kinds, out_dir, timeout_s=3600, only_missing=False, pattern=None):
    cells = list_cells(mesh_kinds)
    if pattern:
        cells = [c for c in cells if pattern in f"{c[0]}:{c[1]}:{c[2]}"]
    results = []
    for arch, shape, mk in cells:
        path = os.path.join(out_dir, cell_filename(arch, shape, mk))
        if only_missing and os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                results.append((arch, shape, mk, prev["status"]))
                continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape,
            "--mesh", mk, "--out", out_dir,
        ]
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, timeout=timeout_s, capture_output=True, text=True)
            ok = proc.returncode == 0
            tail = (proc.stdout + proc.stderr).strip().splitlines()[-8:]
        except subprocess.TimeoutExpired:
            ok, tail = False, ["TIMEOUT"]
        if not ok:
            rec = {
                "arch": arch, "shape": shape, "mesh": mk,
                "status": "error", "error_tail": tail,
            }
            _write(rec, out_dir)
            print(f"[dryrun] ERROR {arch}:{shape}:{mk} ({time.time()-t0:.0f}s)")
            for line in tail:
                print("    " + line)
        else:
            with open(path) as f:
                rec = json.load(f)
            print(
                f"[dryrun] done {arch}:{shape}:{mk} -> {rec['status']} "
                f"({time.time()-t0:.0f}s)"
            )
        results.append((arch, shape, mk, rec["status"]))
    n_ok = sum(1 for r in results if r[3] == "ok")
    n_skip = sum(1 for r in results if r[3] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"[dryrun] SUMMARY: {n_ok} ok, {n_skip} skipped, {n_err} error")
    return 1 if n_err else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--pattern", help="substring filter on arch:shape:mesh")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        return run_all(
            mesh_kinds, args.out, args.timeout, args.only_missing, args.pattern
        )
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    code = 0
    for mk in mesh_kinds:
        try:
            rec = run_cell(args.arch, args.shape, mk, args.out)
            if rec["status"] == "error":
                code = 1
        except Exception:
            traceback.print_exc()
            _write(
                {
                    "arch": args.arch, "shape": args.shape, "mesh": mk,
                    "status": "error",
                    "error_tail": traceback.format_exc().splitlines()[-8:],
                },
                args.out,
            )
            code = 1
    return code


if __name__ == "__main__":
    sys.exit(main())
