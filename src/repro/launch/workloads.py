"""Cell builder: (architecture x shape x mesh) -> lowerable step + specs.

Every dry-run cell is a ``Workload``: a step function, ShapeDtypeStruct input
templates (no allocation), and in/out shardings for the production mesh.
This module is the single source of truth for how each architecture family
is sharded (DESIGN.md §6) — the trainer, server, and dry-run all build their
jitted steps here.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.compat import shard_map as compat_shard_map
from repro.launch.mesh import data_axes
from repro.models import recsys as fm_model
from repro.models import transformer as lm
from repro.models.gnn import dimenet as m_dimenet
from repro.models.gnn import egnn as m_egnn
from repro.models.gnn import gatedgcn as m_gatedgcn
from repro.models.gnn import pna as m_pna
from repro.optim import adamw_init, adamw_update, opt_state_shardings

F32, I32 = jnp.float32, jnp.int32


@dataclasses.dataclass
class Workload:
    name: str
    step: Callable
    input_specs: tuple  # positional ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    model_flops: float  # analytic useful FLOPs (6ND etc.) for §Roofline
    notes: str = ""
    # donated arg positions (params/opt for train, KV cache for decode):
    # the trainer/server donate these, so the dry-run memory analysis must
    # alias them too — otherwise fits-HBM double-counts the state
    donate: tuple = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_model_flops(cfg, tokens: int, kind: str, kv_len: int = 0) -> float:
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence + attention over the cache
    attn = 4.0 * tokens * kv_len * cfg.n_heads * cfg.d_head
    return 2.0 * n * tokens + attn * cfg.n_layers


def build_lm_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Workload:
    cfg = spec.config
    dp = data_axes(mesh)
    dims = shape.dims
    b, s = dims["global_batch"], dims["seq_len"]
    if cfg.is_moe:
        # sort-based MoE dispatch: one token chunk per data shard, experts
        # over the model axis (see models/moe.py)
        n_tok_shards = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        cfg = dataclasses.replace(
            cfg, n_token_shards=n_tok_shards, dp_axes=tuple(dp), ep_axis="model"
        )
    pshard = lm.param_shardings(cfg, mesh, dp=dp)
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    # Sequence-parallel inter-layer residuals: the (B,S,D) activation saved
    # per layer (remat residual) is sharded (batch -> dp, seq -> model) —
    # without the seq axis the 94-layer stacks of the 235B config need
    # ~484 GiB/device (measured); with SP they drop 16x.  GSPMD inserts the
    # all-gather before attention and the reduce-scatter after (classic SP).
    seq_ok = (s % mesh.shape.get("model", 1) == 0) if "model" in mesh.axis_names else False
    dp_act = _ns(mesh, dp, "model" if seq_ok else None, None)

    if shape.kind == "train":
        oshard = opt_state_shardings(pshard, pshapes, mesh, dp=dp)
        oshapes = jax.eval_shape(adamw_init, pshapes)

        logits_sh = _ns(mesh, dp, None, "model")

        def step(params, opt, tokens, labels):
            loss, grads = jax.value_and_grad(lm.loss_fn)(
                params, cfg, tokens, labels, dp_act, logits_sh
            )
            params, opt, gn = adamw_update(
                params, grads, opt,
                mom_shardings=oshard["mu"], param_shardings=pshard,
            )
            return params, opt, loss, gn

        inputs = (
            pshapes,
            oshapes,
            _sds((b, s), I32),
            _sds((b, s), I32),
        )
        in_sh = (pshard, oshard, _ns(mesh, dp, None), _ns(mesh, dp, None))
        out_sh = (pshard, oshard, _ns(mesh), _ns(mesh))
        flops = _lm_model_flops(cfg, b * s, "train")
        return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh,
                        flops, donate=(0, 1))

    if shape.kind == "prefill":
        def step(params, tokens):
            return lm.prefill(params, cfg, tokens, dp_act)

        inputs = (pshapes, _sds((b, s), I32))
        in_sh = (pshard, _ns(mesh, dp, None))
        cache_sh = {
            "k": _ns(mesh, None, dp, "model", None, None),
            "v": _ns(mesh, None, dp, "model", None, None),
        }
        out_sh = (_ns(mesh, dp, None, "model"), cache_sh)
        flops = _lm_model_flops(cfg, b * s, "prefill")
        return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh, flops)

    # decode: one new token against a seq_len KV cache
    def step(params, cache, token, pos):
        return lm.decode_step(params, cfg, cache, token, pos)

    cache_shape = (cfg.n_layers, b, s, cfg.n_kv, cfg.d_head)
    cache_sds = {"k": _sds(cache_shape, jnp.bfloat16), "v": _sds(cache_shape, jnp.bfloat16)}
    cache_sh = {
        "k": _ns(mesh, None, dp, "model", None, None),  # KV sequence-sharded over TP
        "v": _ns(mesh, None, dp, "model", None, None),
    }
    inputs = (pshapes, cache_sds, _sds((b,), I32), _sds((), I32))
    in_sh = (pshard, cache_sh, _ns(mesh, dp), _ns(mesh))
    out_sh = (_ns(mesh, dp, "model"), cache_sh)
    flops = _lm_model_flops(cfg, b, "decode", kv_len=s)
    return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh,
                    flops, donate=(1,))


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

_GNN_MODULES = {
    "dimenet": m_dimenet,
    "egnn": m_egnn,
    "gatedgcn": m_gatedgcn,
    "pna": m_pna,
}


def _gnn_batch_specs(arch: str, n: int, e: int, d: int, n_graphs: int, n_triplets: int):
    """ShapeDtypeStruct batch for a GNN cell (superset per arch needs)."""
    batch = {
        "x": _sds((n, d), F32),
        "edge_index": _sds((2, e), I32),
    }
    if arch == "gatedgcn":
        batch["edge_attr"] = _sds((e, 1), F32)
    if arch in ("gatedgcn", "pna"):
        batch["labels"] = _sds((n,), I32)
        batch["train_mask"] = _sds((n,), F32)
    if arch in ("egnn", "dimenet"):
        batch["pos"] = _sds((n, 3), F32)
        batch["graph_ids"] = _sds((n,), I32)
        batch["y"] = _sds((n_graphs,), F32)
    if arch == "dimenet":
        batch["z"] = _sds((n,), I32)
        batch["triplets"] = _sds((2, n_triplets), I32)
    return batch


def _gnn_batch_shardings(arch: str, batch_specs: dict, mesh, dp):
    """Edge-parallel: edge-indexed arrays over dp, node arrays replicated
    (psum'd segment reductions)."""
    sh = {}
    for k, v in batch_specs.items():
        if k in ("edge_index", "triplets"):
            sh[k] = _ns(mesh, None, dp)
        elif k == "edge_attr":
            sh[k] = _ns(mesh, dp, None)
        else:
            sh[k] = _ns(mesh, *([None] * len(v.shape)))
    return sh


def build_gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Workload:
    arch = spec.name
    mod = _GNN_MODULES[arch]
    dims = shape.dims
    dp = data_axes(mesh)

    if shape.name == "molecule":
        n_graphs = dims["batch"]
        n = dims["n_nodes"] * n_graphs
        e = dims["n_edges"] * n_graphs
        d = 16
    elif shape.name == "minibatch_lg":
        n, e, d = dims["sub_nodes"], dims["sub_edges"], 602
        n_graphs = 1
    else:
        n, e, d = dims["n_nodes"], dims["n_edges"], dims["d_feat"]
        n_graphs = 1
    # edge arrays are sharded over (pod x data); pad to a common multiple —
    # the pipeline pads real batches with zero-weight self-loop edges
    e = (e + 511) // 512 * 512
    n_triplets = min(2 * e, 8_000_000)  # capped triplet sampling (documented)

    cfg = spec.config
    if arch in ("gatedgcn", "pna"):
        cfg = dataclasses.replace(cfg, d_in=d)
    if arch == "egnn":
        cfg = dataclasses.replace(cfg, d_in=d)

    batch_specs = _gnn_batch_specs(arch, n, e, d, n_graphs, n_triplets)
    if arch == "dimenet":
        # z is derived from x in the adapter to keep the x input live
        del batch_specs["x"]
        batch_specs["x"] = _sds((n, d), F32)
    pshapes = jax.eval_shape(lambda: mod.init_params(jax.random.PRNGKey(0), cfg))
    pshard = jax.tree.map(lambda _: _ns(mesh), pshapes)  # replicated (small)

    def loss_adapter(params, batch):
        batch = dict(batch)
        batch["n_graphs"] = n_graphs
        if arch == "dimenet" and "z" not in batch:
            batch["z"] = (
                jnp.abs(batch["x"].sum(-1)).astype(I32) % spec.config.n_species
            )
        if arch == "egnn" and "pos" not in batch:
            batch["pos"] = batch["x"][:, :3]
        return mod.loss_fn(params, cfg, batch)

    oshard = opt_state_shardings(pshard, pshapes, mesh, dp=())
    oshapes = jax.eval_shape(adamw_init, pshapes)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_adapter)(params, batch)
        params, opt, gn = adamw_update(params, grads, opt)
        return params, opt, loss, gn

    if arch == "dimenet" and "z" in batch_specs:
        # keep explicit z (molecule pipeline provides it); derive only if absent
        pass

    inputs = (pshapes, oshapes, batch_specs)
    in_sh = (pshard, oshard, _gnn_batch_shardings(arch, batch_specs, mesh, dp))
    out_sh = (pshard, oshard, _ns(mesh), _ns(mesh))

    # analytic FLOPs: edge-dominated message passing
    h = getattr(cfg, "d_hidden", getattr(cfg, "d_hidden", 64))
    depth = getattr(cfg, "n_layers", getattr(cfg, "n_blocks", 4))
    flops = 6.0 * e * h * h * depth
    if arch == "dimenet":
        flops += 6.0 * n_triplets * h * cfg.n_bilinear * depth
    return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh,
                    flops, donate=(0, 1))


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def build_recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Workload:
    cfg = spec.config
    dp = data_axes(mesh)
    pshard = fm_model.param_shardings(cfg, mesh)
    pshapes = jax.eval_shape(lambda: fm_model.init_params(jax.random.PRNGKey(0), cfg))
    dims = shape.dims

    if shape.kind == "train":
        b = dims["batch"]
        oshard = opt_state_shardings(pshard, pshapes, mesh, dp=dp)
        oshapes = jax.eval_shape(adamw_init, pshapes)

        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(fm_model.loss_fn)(params, cfg, batch)
            params, opt, gn = adamw_update(params, grads, opt)
            return params, opt, loss, gn

        batch_specs = {"ids": _sds((b, cfg.n_fields), I32), "labels": _sds((b,), F32)}
        batch_sh = {"ids": _ns(mesh, dp, None), "labels": _ns(mesh, dp)}
        inputs = (pshapes, oshapes, batch_specs)
        in_sh = (pshard, oshard, batch_sh)
        out_sh = (pshard, oshard, _ns(mesh), _ns(mesh))
        flops = 6.0 * b * cfg.n_fields * cfg.embed_dim
        return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh,
                        flops, donate=(0, 1))

    # Serving shardings (§Perf hillclimb: fm:serve_bulk): the table is
    # read-only at serve time and fits HBM (1.4 GiB f32), so it is
    # REPLICATED — lookups become device-local gathers and the cross-model
    # all-reduce of partial embedding sums (28 MB/step, the dominant term of
    # the baseline) disappears; the batch shards over the WHOLE mesh.
    serve_pshard = jax.tree.map(lambda _: _ns(mesh), pshapes)
    all_axes = tuple(mesh.axis_names)

    if shape.kind == "serve":
        b = dims["batch"]

        def step(params, batch):
            return fm_model.serve_step(params, cfg, batch)

        batch_specs = {"ids": _sds((b, cfg.n_fields), I32)}
        inputs = (pshapes, batch_specs)
        in_sh = (serve_pshard, {"ids": _ns(mesh, all_axes, None)})
        out_sh = _ns(mesh, all_axes)
        flops = 2.0 * b * cfg.n_fields * cfg.embed_dim
        return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh, flops)

    # retrieval: one query vs n_candidates (candidates sharded over the mesh;
    # padded to a mesh-divisible count — the pipeline pads with sentinel rows)
    nc = (dims["n_candidates"] + 511) // 512 * 512

    def step(params, user_ids, cand_rows):
        return fm_model.retrieval_scores(params, cfg, user_ids, cand_rows)

    inputs = (pshapes, _sds((1, cfg.n_fields), I32), _sds((nc,), I32))
    in_sh = (serve_pshard, _ns(mesh, None, None), _ns(mesh, all_axes))
    out_sh = _ns(mesh, all_axes)
    flops = 2.0 * nc * cfg.embed_dim
    return Workload(f"{spec.name}:{shape.name}", step, inputs, in_sh, out_sh, flops)


# ---------------------------------------------------------------------------
# sameAs engine (the paper's workload)
# ---------------------------------------------------------------------------

def build_engine_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Workload:
    from repro.core.engine_jax import build_plans, eval_plan, process_candidates
    from repro.core.rules import Rule
    from repro.core.terms import SAME_AS, var

    dims = shape.dims
    cap = dims["capacity"]  # per-device arena rows
    n_res = dims["n_resources"]
    axes = tuple(mesh.axis_names)  # flatten the whole mesh for the engine
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))
    cfg = spec.config
    bind_cap, out_cap, rw_cap = cfg.bind_cap, cfg.out_cap, cfg.rewrite_cap

    # representative 2-atom join rule: <x1', x2, x3> <- <x1,x2,x3> & <x1,~,x1'>
    rule = Rule((var(4), var(2), var(3)), ((var(1), var(2), var(3)), (var(1), SAME_AS, var(4))))
    plan = tuple(build_plans(rule, full=False)[0])
    head_slots = tuple(t if t < 0 else None for t in rule.head)

    def step(spo, epoch, marked, tomb, n_used, rep, sort_perm, sorted_keys,
             atom_consts, head_consts, r):
        heads, valid, n_d, n_a, ov_b, ov_o = eval_plan(
            spo, epoch, marked, tomb, sorted_keys, sort_perm, r,
            atom_consts, head_consts,
            plan=plan, head_var_slots=head_slots,
            bind_cap=bind_cap, out_cap=out_cap, axis=axes,
        )
        return process_candidates(
            spo, epoch, marked, n_used, rep, sort_perm, sorted_keys,
            heads, valid, r,
            rewrite_cap=rw_cap, axis=axes, n_shards=n_dev,
            route_cap=cfg.route_cap,
        )

    smap = compat_shard_map(
        step,
        mesh=mesh,
        in_specs=(P(axes), P(axes), P(axes), P(axes), P(axes), P(),
                  P(axes), P(axes), P(), P(), P()),
        out_specs=(
            P(axes), P(axes), P(axes), P(axes), P(), P(axes), P(axes),
            {
                "rep_changed": P(), "contradiction": P(),
                "ov_rewrite": P(axes), "ov_store": P(axes), "ov_route": P(axes),
                "ov_pair": P(axes),
                "n_new": P(axes), "n_pairs": P(), "n_marked": P(axes),
                "n_reflexive": P(axes), "delta_rows": P(axes),
                "delta_valid": P(axes),
            },
        ),
    )

    rows = (cap + 1) * n_dev
    inputs = (
        _sds((rows, 3), I32), _sds((rows,), I32), _sds((rows,), jnp.bool_),
        _sds((rows,), I32),
        _sds((n_dev,), I32), _sds((n_res,), I32),
        _sds((rows,), I32), _sds((rows,), jnp.int64),
        _sds((2, 3), I32), _sds((3,), I32), _sds((), I32),
    )
    in_sh = tuple(
        [_ns(mesh, axes, None), _ns(mesh, axes), _ns(mesh, axes), _ns(mesh, axes),
         _ns(mesh, axes), _ns(mesh), _ns(mesh, axes), _ns(mesh, axes),
         _ns(mesh), _ns(mesh), _ns(mesh)]
    )
    out_sh = None  # let SPMD infer from shard_map out_specs
    # one round over a full arena: joins ~ sort+search over cap rows/device
    flops = float(n_dev * cap * np.log2(max(cap, 2)) * 8)
    return Workload(
        f"{spec.name}:{shape.name}", smap, inputs, in_sh, out_sh, flops,
        notes="one SPMD materialisation round (join plan + process)",
    )


def build_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Workload:
    if spec.family == "lm":
        return build_lm_cell(spec, shape, mesh)
    if spec.family == "gnn":
        return build_gnn_cell(spec, shape, mesh)
    if spec.family == "recsys":
        return build_recsys_cell(spec, shape, mesh)
    if spec.family == "engine":
        return build_engine_cell(spec, shape, mesh)
    raise ValueError(spec.family)
