"""Roofline analysis over the dry-run artifacts (single-pod mesh).

Reads ``artifacts/dryrun/*__single.json`` and derives, per (arch x shape):

  compute_s    = HLO_FLOPs_per_dev   / peak_FLOP/s        (197 TF/s bf16)
  memory_s     = HLO_bytes_per_dev   / HBM_bw             (819 GB/s)
  collective_s = coll_bytes_per_dev  / ICI link bw        (50 GB/s)

All inputs are per-chip numbers taken from the partitioned SPMD module, so
dividing by per-chip peaks is equivalent to the assignment's
``global / (chips x peak)`` form.  Additionally:

  model_flops_ratio = MODEL_FLOPS / (HLO_FLOPs_per_dev x chips)
      — how much compiled compute is "useful" (remat/dup waste shows here),
  roofline_frac = useful-compute-time / dominant-term
      — the score: 1.0 means the step runs at the hardware roofline on its
        dominant resource while doing only model math.

Usage:
  python -m repro.launch.roofline [--dir artifacts/dryrun] [--mesh single]
  python -m repro.launch.roofline --markdown > roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun import ART_DIR, HW


def load_cells(art_dir: str, mesh: str = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


# ring-algorithm wire factors per operand byte: all-reduce moves ~2x
# (reduce-scatter + all-gather phases); others ~1x.  Makes all-reduce ->
# reduce-scatter rewrites visible in the collective term.
WIRE_WEIGHT = {"all-reduce": 2.0}


def analyse(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    flops = rec["cost"]["flops_per_dev"]
    mem_bytes = rec["cost"]["bytes_per_dev"]
    coll = sum(
        v["bytes"] * WIRE_WEIGHT.get(k, 1.0)
        for k, v in rec["collectives"]["by_kind"].items()
    )
    compute_s = flops / HW["peak_flops_bf16"]
    memory_s = mem_bytes / HW["hbm_bytes_per_s"]
    collective_s = coll / HW["ici_bytes_per_s_per_link"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_flops = rec.get("model_flops_global", 0.0)
    hlo_global = flops * n
    useful_s = model_flops / (n * HW["peak_flops_bf16"])
    dom_s = terms[dominant]
    return {
        "cell": f"{rec['arch']}:{rec['shape']}",
        "mesh": rec["mesh"],
        "n_devices": n,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "model_flops_ratio": (model_flops / hlo_global) if hlo_global else 0.0,
        "roofline_frac": (useful_s / dom_s) if dom_s > 0 else 0.0,
        "peak_mem_gib": rec["memory"]["peak_bytes"] / 2**30,
        "fits_hbm": rec["memory"]["fits_hbm"],
        "tpu_mem_gib": rec["memory"].get("tpu_est_bytes", rec["memory"]["peak_bytes"]) / 2**30,
        "fits_tpu": rec["memory"].get("fits_hbm_tpu_est", rec["memory"]["fits_hbm"]),
        "coll_by_kind": {
            k: v["bytes"] for k, v in rec["collectives"]["by_kind"].items()
        },
    }


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def markdown_table(rows: list[dict], skipped: list[dict]) -> str:
    out = [
        "| cell | devs | compute | memory | collective | dominant | "
        "model/HLO FLOPs | roofline frac | mem GiB (fits) | TPU-est GiB (fits) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['cell']} | {r['n_devices']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['model_flops_ratio']:.2f} "
            f"| {r['roofline_frac']:.3f} "
            f"| {r['peak_mem_gib']:.2f} ({'y' if r['fits_hbm'] else 'N'}) "
            f"| {r['tpu_mem_gib']:.2f} ({'y' if r['fits_tpu'] else 'N'}) |"
        )
    for s in skipped:
        out.append(
            f"| {s['arch']}:{s['shape']} | — | — | — | — | — | — | — | "
            f"skipped: {s.get('skip_reason','')[:60]} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.abspath(ART_DIR))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cells = load_cells(args.dir, args.mesh)
    rows, skipped, errors = [], [], []
    for rec in cells:
        if rec.get("status") == "skipped":
            skipped.append(rec)
        elif rec.get("status") == "error":
            errors.append(rec)
        else:
            a = analyse(rec)
            if a:
                rows.append(a)
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    print(markdown_table(rows, skipped))
    if errors:
        print(f"\n{len(errors)} cells in error state:")
        for e in errors:
            print(f"  {e['arch']}:{e['shape']}:{e['mesh']}")


if __name__ == "__main__":
    main()
