"""JAX version compatibility shims (policy: docs/incremental.md §compat).

The repo targets the jax that ships in the container (0.4.x today) while
staying forward-compatible with the 0.5+/0.6+ API renames.  Three surfaces
moved between those lines:

  * ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist on jax >= 0.5 — older meshes are implicitly
    all-Auto, so omitting the kwarg is semantically identical,
  * ``jax.shard_map`` (with ``check_vma=``) is the 0.6 name for
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep=``),
  * ``Compiled.cost_analysis()`` returns a dict on new jax but a
    single-element ``list[dict]`` on 0.4.x.

Everything else in the repo must go through these helpers instead of
feature-sniffing jax inline.
"""

from __future__ import annotations

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def axis_types_auto(n: int):
    """``axis_types=`` value for an n-axis all-Auto mesh; None on old jax
    (whose meshes are implicitly Auto and reject the kwarg)."""
    if not HAS_AXIS_TYPE:
        return None
    return (jax.sharding.AxisType.Auto,) * n


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_types = axis_types_auto(len(axes))
    if axis_types is not None:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(shape, axes, **kwargs)


def shard_map(fn, *, mesh, in_specs, out_specs):
    """Unchecked shard_map across the 0.4 -> 0.6 API rename.

    Replication/VMA checking is disabled on both paths: the engine's round
    body mixes replicated and sharded outputs in ways the checker rejects
    (the all-gather/all_to_all exchanges are hand-verified instead).
    """
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def xla_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version.

    jax 0.4.x returns ``[per_program_dict]``; 0.5+ returns the dict itself.
    An empty analysis normalises to ``{}``.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
