"""Materialisation statistics mirroring the paper's Table 2 columns."""

from __future__ import annotations

import dataclasses
import threading
from collections import Counter


class DispatchCounter:
    """Runtime side of the dispatch auditor (``JaxEngine.dispatches``).

    Every call through the engine's fn cache records one dispatch under
    its *family* (the cache-key head: "plan", "process", "seed_tombs", ...)
    and, when a maintenance generator has tagged the current phase via the
    ``phase`` attribute, under that ``(phase, family)`` pair.  First-time
    cache fills are tallied separately in ``compiles`` so steady-state
    dispatch rates can be read net of compilation.  The static half lives
    in :func:`repro.core.incremental_spmd.static_dispatch_profile`;
    :func:`repro.analysis.dispatch_crosscheck` reconciles the two.

    **Thread safety** (the serving tier runs maintenance on a worker thread
    while reader threads dispatch batched query fns): ``phase`` is
    *thread-local* — the maintenance generators' tags can never leak onto a
    concurrent reader's ``"query"`` dispatches or vice versa — and the
    counter increments take a lock so totals stay exact under concurrency
    (a bare ``Counter[k] += 1`` is a read-modify-write that can drop
    increments between threads).
    """

    def __init__(self) -> None:
        self.by_family: Counter = Counter()
        self.by_phase: Counter = Counter()   # keyed (phase, family)
        self.compiles: Counter = Counter()   # first-time cache fills
        self._phase = threading.local()      # set by the phase generators
        self._lock = threading.Lock()

    @property
    def phase(self) -> str | None:
        return getattr(self._phase, "value", None)

    @phase.setter
    def phase(self, value: str | None) -> None:
        self._phase.value = value

    @property
    def total(self) -> int:
        return sum(self.by_family.values())

    def record(self, family: str) -> None:
        with self._lock:
            self.by_family[family] += 1
            self.by_phase[(self.phase, family)] += 1

    def record_compile(self, family: str) -> None:
        with self._lock:
            self.compiles[family] += 1

    def snapshot(self) -> dict:
        """Immutable totals for delta-ing around a timed region."""
        return {
            "by_family": dict(self.by_family),
            "total": self.total,
        }

    def reset(self) -> None:
        self.by_family.clear()
        self.by_phase.clear()
        self.compiles.clear()


@dataclasses.dataclass
class MatStats:
    """Counters collected during materialisation.

    ``derivations`` counts (rule, substitution) pairs that produce a head fact
    (duplicates included) — the paper's 'Derivations' column.  ``rule_applications``
    counts (rule, body-position, delta-fact) partial instantiations attempted —
    the paper's 'Rule appl.' column.  ``triples_total`` / ``triples_unmarked``
    mirror 'Triples after (total / unmarked)'.
    """

    mode: str = "REW"
    derivations: int = 0
    rule_applications: int = 0
    merged_resources: int = 0
    sameas_pairs: int = 0
    reflexive_added: int = 0
    rounds: int = 0
    rule_rewrites: int = 0          # how many times P' := rho(P) changed P'
    rules_requeued: int = 0         # rules placed on the R queue analogue
    od_waves: int = 0               # overdelete waves (incremental deletes)
    index_rebuilds: int = 0         # full argsorts of the arena index (<=1/epoch)
    overdeleted: int = 0            # rows tombstoned across deletes
    suspects_split: int = 0         # sameAs cliques split + re-merged
    rederive_targeted: int = 0      # delete-side rules evaluated head-bound
    rederive_full_fallback: int = 0 # delete-side whole-rule requeues (const heads)
    rederive_seed_rows: int = 0     # overdeleted head instances joined backward
    rederive_join_width: int = 0    # widest padded rederive seed table
    full_plan_evals: int = 0        # unconstrained full-plan rule evaluations
    remerge_targeted: int = 0       # forward-side rules evaluated merge-anchored
    remerge_full_fallback: int = 0  # forward-side whole-rule requeues (ground atoms)
    delta_mask_fallbacks: int = 0   # delta windows that overflowed to all-True masks
    capacity_retries: int = 0       # mid-operation rollback+grow restarts
    wide_growth_restarts: int = 0   # retries that grew a wide (base-run) cap
    triples_total: int = 0          # arena rows used (marked + unmarked)
    triples_unmarked: int = 0
    triples_explicit: int = 0
    wall_seconds: float = 0.0
    contradiction: bool = False
    memory_bytes: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def factor_over(self, other: "MatStats") -> dict:
        """Ratios AX/REW as in the paper's 'factor' rows."""

        def ratio(a, b):
            return float(a) / float(b) if b else float("inf")

        return {
            "triples": ratio(other.triples_unmarked, self.triples_unmarked),
            "rule_applications": ratio(other.rule_applications, self.rule_applications),
            "derivations": ratio(other.derivations, self.derivations),
            "time": ratio(other.wall_seconds, self.wall_seconds),
        }
