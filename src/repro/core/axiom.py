"""The owl:sameAs axiomatisation P~= (paper §3, rules ~=1 .. ~=5).

AX mode materialises ``[P u P~=]^inf(E)`` by adding these rules to the user
program.  ~=5 (owl:differentFrom contradiction) is enforced as a check rather
than a rule with a ``false`` head.
"""

from __future__ import annotations

from .rules import Program, Rule
from .terms import DIFFERENT_FROM, SAME_AS, var

X1, X2, X3, X1P, X2P, X3P = (var(i) for i in range(1, 7))


def sameas_axiomatisation() -> Program:
    """Rules ~=1 (three instances) and ~=2..~=4.

    ~=1_i:  <x_i, sameAs, x_i> <- <x1, x2, x3>
    ~=2..4: replacement in subject / predicate / object position.
    """
    rules = [
        # ~=1, one per position
        Rule((X1, SAME_AS, X1), ((X1, X2, X3),)),
        Rule((X2, SAME_AS, X2), ((X1, X2, X3),)),
        Rule((X3, SAME_AS, X3), ((X1, X2, X3),)),
        # ~=2: subject replacement
        Rule((X1P, X2, X3), ((X1, X2, X3), (X1, SAME_AS, X1P))),
        # ~=3: predicate replacement
        Rule((X1, X2P, X3), ((X1, X2, X3), (X2, SAME_AS, X2P))),
        # ~=4: object replacement
        Rule((X1, X2, X3P), ((X1, X2, X3), (X3, SAME_AS, X3P))),
    ]
    return Program(rules)


def with_axiomatisation(program: Program) -> Program:
    return Program(list(program.rules) + list(sameas_axiomatisation().rules))


def is_contradiction(s: int, p: int, o: int) -> bool:
    """Rule ~=5: false <- <x, owl:differentFrom, x>."""
    return p == DIFFERENT_FROM and s == o
