"""Representative map rho as a union-find.

The paper implements rho with CAS-based lock-free ``mergeInto`` (Algorithm 5)
plus per-clique linked lists.  TPUs have no CAS, so the adaptation (DESIGN.md
S2) is the classic data-parallel equivalence closure:

  * **min-hooking**: all sameAs pairs of a round are applied at once with a
    conflict-free ``scatter-min`` (``rep[hi] = min(rep[hi], lo)``),
  * **pointer doubling**: ``rep = rep[rep]`` iterated to full path compression.

The representative of a clique is its minimum resource ID — a concrete
instance of the paper's "arbitrary total order" used to prevent cyclic merges,
with the bonus that the result is order-independent and deterministic.

Two interchangeable implementations:
  * ``merge_pairs_np`` — plain numpy (reference engine),
  * ``merge_pairs_jax`` — pure ``jax.lax`` control flow, jittable; the
    pointer-doubling step can be served by the Pallas kernel
    :mod:`repro.kernels.pointer_jump` on TPU.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# numpy reference
# ---------------------------------------------------------------------------

def compress_np(rep: np.ndarray) -> np.ndarray:
    """Full path compression by pointer doubling (O(log depth) sweeps)."""
    rep = rep.copy()
    while True:
        nxt = rep[rep]
        if np.array_equal(nxt, rep):
            return rep
        rep = nxt


def merge_pairs_np(rep: np.ndarray, pairs: np.ndarray) -> tuple[np.ndarray, int]:
    """Merge (a, b) rows of ``pairs`` into ``rep``; returns (rep', n_merged).

    ``n_merged`` counts resources whose representative changed — the paper's
    'Merged resources' column counts each resource merged once, which holds
    here because a non-root never becomes a root again.
    """
    if pairs.size == 0:
        return rep, 0
    rep = compress_np(rep)
    before_roots = int((rep == np.arange(rep.shape[0])).sum())
    a = rep[pairs[:, 0]]
    b = rep[pairs[:, 1]]
    while True:
        lo = np.minimum(a, b)
        hi = np.maximum(a, b)
        active = lo != hi
        if not active.any():
            break
        # conflict-free scatter-min hooking
        np.minimum.at(rep, hi[active], lo[active])
        rep = compress_np(rep)
        a = rep[a]
        b = rep[b]
    after_roots = int((rep == np.arange(rep.shape[0])).sum())
    return rep, before_roots - after_roots


# ---------------------------------------------------------------------------
# jax implementation (jit-compatible, static shapes)
# ---------------------------------------------------------------------------

def _compress_jax(rep: jnp.ndarray) -> jnp.ndarray:
    def cond(state):
        rep, done = state
        return ~done

    def body(state):
        rep, _ = state
        nxt = rep[rep]
        return nxt, jnp.array_equal(nxt, rep)

    rep, _ = jax.lax.while_loop(cond, body, (rep, jnp.asarray(False)))
    return rep


def merge_pairs_jax(rep: jnp.ndarray, pairs: jnp.ndarray, pair_valid: jnp.ndarray) -> jnp.ndarray:
    """Batched merge under a validity mask; shapes are static.

    ``pairs`` is (m, 2) int32 with garbage rows masked out by ``pair_valid``.
    """
    n = rep.shape[0]
    rep = _compress_jax(rep)

    def cond(state):
        rep, a, b = state
        return jnp.any((a != b) & pair_valid)

    def body(state):
        rep, a, b = state
        lo = jnp.minimum(a, b)
        hi = jnp.maximum(a, b)
        active = (lo != hi) & pair_valid
        # masked scatter-min: inactive rows write to a dummy slot (their own lo)
        tgt = jnp.where(active, hi, 0)
        val = jnp.where(active, lo, rep[0])
        rep = rep.at[tgt].min(val)
        rep = _compress_jax(rep)
        return rep, rep[a], rep[b]

    a = rep[jnp.where(pair_valid, pairs[:, 0], 0)]
    b = rep[jnp.where(pair_valid, pairs[:, 1], 0)]
    rep, _, _ = jax.lax.while_loop(cond, body, (rep, a, b))
    return rep


# ---------------------------------------------------------------------------
# clique utilities (host)
# ---------------------------------------------------------------------------

def _sizes_compressed(rep: np.ndarray) -> np.ndarray:
    return np.bincount(rep, minlength=rep.shape[0])


def clique_sizes(rep: np.ndarray) -> np.ndarray:
    """sizes[r] = |clique represented by r| (1 for singletons, 0 for non-roots)."""
    return _sizes_compressed(compress_np(np.asarray(rep)))


def split_cliques(rep: np.ndarray, suspect_reps: np.ndarray) -> np.ndarray:
    """Reset every member of the suspect cliques to a singleton.

    The inverse of min-hooking: members (including the representative
    itself) become their own roots, and the incremental delete path's
    forward pass re-merges whatever equalities the surviving facts still
    support via :func:`merge_pairs_np` / :func:`merge_pairs_jax` — only the
    affected connected components are ever recomputed.
    """
    if suspect_reps.shape[0] == 0:
        return rep
    rep = rep.copy()
    members = clique_members(rep)
    for r in suspect_reps:
        mem = members.get(int(r))
        if mem is not None:
            rep[mem] = mem.astype(rep.dtype)
    return compress_np(rep)


def _members_compressed(rep: np.ndarray) -> dict[int, np.ndarray]:
    order = np.argsort(rep, kind="stable")
    sorted_rep = rep[order]
    out: dict[int, np.ndarray] = {}
    boundaries = np.flatnonzero(np.diff(sorted_rep)) + 1
    for seg in np.split(order, boundaries):
        if seg.shape[0] > 1:
            out[int(rep[seg[0]])] = np.sort(seg)
    return out


def clique_members(rep: np.ndarray) -> dict[int, np.ndarray]:
    """representative -> member array, only for cliques of size > 1."""
    return _members_compressed(compress_np(np.asarray(rep)))


class FrozenRho:
    """Immutable, fully-compressed view of rho with cached clique structure.

    The SPARQL executor needs ``compress_np`` plus the clique expansion
    tables (``clique_members`` / ``clique_sizes``) for every answer; a
    standing service evaluates many queries against the *same* maintenance
    epoch's rho, so the epoch snapshot freezes the compression once and the
    expansion tables are built lazily and shared across all of the epoch's
    queries.  The underlying array is marked read-only so the view can be
    handed to concurrent readers without defensive copies.
    """

    __slots__ = ("rep", "_members", "_sizes", "_order", "_sorted_rep")

    def __init__(self, rep: np.ndarray) -> None:
        rep = compress_np(np.asarray(rep))
        rep.setflags(write=False)
        self.rep = rep
        self._members: dict[int, np.ndarray] | None = None
        self._sizes: np.ndarray | None = None
        self._order: np.ndarray | None = None
        self._sorted_rep: np.ndarray | None = None

    def __len__(self) -> int:
        return int(self.rep.shape[0])

    @property
    def members(self) -> dict[int, np.ndarray]:
        if self._members is None:
            # rep is compressed by construction: skip the redundant sweep
            self._members = _members_compressed(self.rep)
        return self._members

    @property
    def sizes(self) -> np.ndarray:
        if self._sizes is None:
            self._sizes = _sizes_compressed(self.rep)
        return self._sizes

    def normalise(self, ids: np.ndarray) -> np.ndarray:
        """rho-normal form of an int index array (e.g. an (n, 3) batch)."""
        return self.rep[ids]

    def _csr(self) -> tuple[np.ndarray, np.ndarray]:
        # resources grouped by representative: members of rep r are the
        # contiguous run order[searchsorted(sorted_rep, r, left:right)]
        if self._order is None:
            self._order = np.argsort(self.rep, kind="stable")
            self._sorted_rep = self.rep[self._order]
        return self._order, self._sorted_rep

    def expand_ids(self, col: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorised clique expansion of a resource-id column.

        Returns ``(row_idx, vals)``: each input row ``i`` contributes one
        output row per member of ``col[i]``'s clique (``row_idx`` repeats
        ``i``, ``vals`` holds the member ids).  An id that is nobody's
        representative — including ids unseen by this rho — expands to
        itself, matching the ``members.get(x, [x])`` singleton convention.
        One searchsorted + gather pass instead of a Python loop over rows:
        the executor's per-answer expansion cost for serving-size bags.
        """
        col = np.asarray(col)
        if col.shape[0] <= 64 and self._members is not None:
            # point-lookup answers: a handful of rows, members table already
            # built (serving pre-warms it at publish) — a direct dict probe
            # per row undercuts the fixed cost of the vectorised pass
            ridx: list[int] = []
            vlist: list[np.ndarray] = []
            for i, x in enumerate(col.tolist()):
                mem = self._members.get(x)
                if mem is None:
                    ridx.append(i)
                    vlist.append(np.asarray([x]))
                else:
                    ridx.extend([i] * mem.shape[0])
                    vlist.append(mem)
            vals = (np.concatenate(vlist) if vlist
                    else np.zeros(0, col.dtype))
            return (np.asarray(ridx, dtype=np.int64),
                    vals.astype(col.dtype, copy=False))
        order, srep = self._csr()
        starts = np.searchsorted(srep, col, side="left")
        counts = np.searchsorted(srep, col, side="right") - starts
        lone = counts == 0
        counts = np.where(lone, 1, counts)
        row_idx = np.repeat(np.arange(col.shape[0]), counts)
        offs = np.concatenate([[0], np.cumsum(counts)])
        within = np.arange(row_idx.shape[0]) - offs[row_idx]
        gathered = order[
            np.minimum(starts[row_idx] + within, order.shape[0] - 1)
        ] if order.shape[0] else np.zeros(row_idx.shape[0], col.dtype)
        vals = np.where(lone[row_idx], col[row_idx], gathered)
        return row_idx, vals.astype(col.dtype, copy=False)

    def refreshed(self, rep: np.ndarray) -> "FrozenRho":
        """An epoch-over-epoch *incremental* refresh of the frozen view.

        Serving publishes one FrozenRho per maintenance epoch, and most
        epochs touch few (often zero) cliques, so rebuilding the clique
        expansion tables from scratch — an argsort over every resource —
        charges every epoch for work proportional to the whole resource
        space.  ``refreshed`` compares ``rep`` against this view and:

          * returns ``self`` when nothing changed (the common plain-add
            epoch) — the cached ``members``/``sizes`` carry over for free;
          * otherwise builds the successor view, recomputing members only
            for the *affected* cliques (any clique that gained or lost a
            member has some resource whose representative changed, so the
            affected set is exactly the old+new representatives of the
            changed resources, plus everything in a freshly interned tail);
            untouched cliques keep their cached member arrays by reference.

        ``sizes`` is always a fresh O(n) bincount — it is cheap and keeps
        the invariant trivial.  Falls back to a plain rebuild when this
        view's member table was never materialised (nothing to reuse).
        """
        rep = compress_np(np.asarray(rep))
        n_old, n_new = self.rep.shape[0], rep.shape[0]
        if n_new == n_old and np.array_equal(rep, self.rep):
            return self
        if self._members is None:
            return FrozenRho(rep)
        n = min(n_old, n_new)
        changed = np.flatnonzero(rep[:n] != self.rep[:n])
        affected = np.union1d(self.rep[changed], rep[changed])
        if n_new > n:  # freshly interned resources and their merge targets
            tail = np.arange(n, n_new)
            affected = np.union1d(affected, np.union1d(tail, rep[tail]))
        out = FrozenRho.__new__(FrozenRho)
        rep.setflags(write=False)
        out.rep = rep
        out._sizes = None
        out._order = None
        out._sorted_rep = None
        members = {
            r: m for r, m in self._members.items() if r not in set(affected.tolist())
        }
        if affected.shape[0]:
            sub = np.flatnonzero(np.isin(rep, affected.astype(rep.dtype)))
            sr = rep[sub]
            order = np.argsort(sr, kind="stable")
            sub, sr = sub[order], sr[order]
            bounds = np.flatnonzero(np.diff(sr)) + 1
            for seg in np.split(sub, bounds):
                if seg.shape[0] > 1:
                    members[int(rep[seg[0]])] = np.sort(seg)
        out._members = members
        return out
