"""Fused on-device fixpoint rounds: one compiled ``lax.while_loop`` per pass.

The host round loop in :meth:`repro.core.engine_jax.JaxEngine._forward` (and
the overdelete wave loop of :mod:`repro.core.incremental_spmd`) dispatches
one process step, one plan evaluation per delta plan and one squeeze PER
ROUND, with a device->host round trip between rounds to read the convergence
and overflow flags.  At steady state that dispatch count — not sort
bandwidth — is the per-event floor (ROADMAP "kill the dispatch floor";
BENCH_incremental.json records it as ``dispatches_per_event``).

This module moves the whole inner loop into a single compiled fixpoint:

* :func:`fused_forward_rounds` — the forward round loop (process ->
  delta-plan evaluation -> squeeze) as one ``lax.while_loop`` whose carry
  holds the arena columns, the candidate stream and sticky overflow/exit
  flags.  Convergence is decided on device; capacity overflow, contradiction
  and rho-reaches-a-rule-constant are checked ONCE on exit, not per round.
* :func:`fused_delete_waves` — the DRed overdelete wave loop (tombstone
  plans -> :func:`~repro.core.incremental_spmd._od_step`) fused the same
  way.

Host-only decisions stay host decisions, but move from per-round to
per-exit:

* **Capacity retry** — every overflow flag is a sticky carry bool; the loop
  exits on the first raised flag and the host raises the usual
  :class:`~repro.core.engine_jax.CapacityError`, whose snapshot rollback
  makes the (garbage) post-overflow carry state irrelevant.
* **Rule rewriting** — rules are rewritten on the host when rho reaches a
  rule *constant*.  The invariant at entry is that every constant is a rho
  fixed point (the program is always rewritten under a compressed rho), so
  the device detects the exit condition exactly as
  ``any(rep[c] != c for rule constants c)`` against the post-merge rep.
  The exit iteration's plan evaluation is *nullified* by evaluating at an
  impossible round (every epoch predicate matches nothing — see
  ``_epoch_ok``), and the host re-evaluates that round's plans with the
  rewritten constants before resuming — so plans run exactly once per
  round, with the same constants the host loop would have used.

Both fns register with the trace audit (``fforward`` / ``fwave``): the
while_loop body must lint clean under NoArenaSort / NoArenaScatter (fwave
carries the od step's deliberate exemption) / DtypeSafety.
"""

from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .engine_jax import (
    I32,
    _pow2,
    _squeeze_stream,
    build_plans,
    eval_plan,
    process_candidates,
    register_auditable,
)
from .terms import is_var

__all__ = [
    "forward_plan_signature",
    "fused_delete_waves",
    "fused_forward_rounds",
    "program_tables",
]


def forward_plan_signature(program, tombstone: bool = False) -> tuple:
    """Static plan signature of a program: one ``(rule_idx, plan,
    head_var_slots)`` entry per delta (or tombstone) plan — the static half
    the fused fns close over (the traced half is :func:`program_tables`)."""
    sig = []
    for k, rule in enumerate(program.rules):
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        for plan in build_plans(rule, full=False, tombstone=tombstone):
            sig.append((k, tuple(plan), head_slots))
    return tuple(sig)


def program_tables(program):
    """Traced constant tables of a program.

    Returns ``(atom_consts, head_consts, const_vals, const_valid)``:

    * ``atom_consts`` (n_rules, max_atoms, 3) / ``head_consts`` (n_rules, 3)
      int32 — the per-rule constant arrays :func:`eval_plan` takes (variable
      positions hold garbage 0, exactly like the host driver builds them);
    * ``const_vals`` / ``const_valid`` — the deduplicated set of every rule
      constant, padded to a power of two.  Rule rewriting is a host
      decision; the device only needs to detect *when* it is due, and the
      rule-constant invariant (every constant is a rho fixed point at
      operation entry) makes that exactly
      ``any(const_valid & (rep[const_vals] != const_vals))``.

    Constants are traced arguments (as everywhere in the engine) so a host
    rewrite never re-traces the fused fn.
    """
    rules = program.rules
    n_rules = max(len(rules), 1)
    max_atoms = max((len(r.body) for r in rules), default=1)
    ac = np.zeros((n_rules, max(max_atoms, 1), 3), np.int32)
    hc = np.zeros((n_rules, 3), np.int32)
    consts: set[int] = set()
    for k, rule in enumerate(rules):
        for j, atom in enumerate(rule.body):
            for pos, t in enumerate(atom):
                if not is_var(t):
                    ac[k, j, pos] = t
                    consts.add(int(t))
        for pos, t in enumerate(rule.head):
            if not is_var(t):
                hc[k, pos] = t
                consts.add(int(t))
    cs = np.asarray(sorted(consts), np.int32)
    width = _pow2(max(cs.shape[0], 1))
    vals = np.zeros((width,), np.int32)
    vals[: cs.shape[0]] = cs
    valid = np.arange(width) < cs.shape[0]
    return (
        jnp.asarray(ac), jnp.asarray(hc),
        jnp.asarray(vals), jnp.asarray(valid),
    )


# round sentinel for the nullified exit iteration: far below any real round,
# so every epoch/tombstone predicate of ``_epoch_ok`` matches zero rows and
# the iteration's plan evaluation contributes exactly nothing (collectives
# still run — a ``cond`` around them would diverge across shards)
_NULL_ROUND = -(1 << 20)


def _pany(x, axis):
    x = jnp.any(x)
    if axis is None:
        return x
    return jax.lax.psum(x.astype(I32), axis) > 0


def _eval_plans(
    spo, epoch, marked, tomb, sorted_keys, sort_perm, r_eval,
    atom_consts, head_consts, plans, width,
    *, bind_cap, plan_out_cap, axis, use_kernel,
):
    """Evaluate the static ``plans`` and squeeze/pad the bucketed heads to
    ``width`` rows.  The fused analogue of the host loop's per-round
    ``_eval_rule`` + ``_bucket_cands`` + squeeze — one traced block instead
    of one dispatch per plan.  Returns
    ``(heads, valid, n_deriv, n_appl, ov_bind, ov_out, ov_squeeze)``
    (scalars local to the shard; callers psum)."""
    outs, vals = [], []
    n_deriv = jnp.zeros((), I32)
    n_appl = jnp.zeros((), I32)
    ov_bind = jnp.zeros((), bool)
    ov_out = jnp.zeros((), bool)
    ov_squeeze = jnp.zeros((), bool)
    for k, plan_t, head_slots in plans:
        o, v, nd, na, ovb, ovo = eval_plan(
            spo, epoch, marked, tomb, sorted_keys, sort_perm, r_eval,
            atom_consts[k], head_consts[k],
            plan=plan_t, head_var_slots=head_slots,
            bind_cap=bind_cap, out_cap=plan_out_cap, axis=axis,
            use_kernel=use_kernel,
        )
        outs.append(o)
        vals.append(v)
        n_deriv = n_deriv + nd.reshape(())
        n_appl = n_appl + na.reshape(())
        ov_bind = ov_bind | jnp.any(ovb)
        ov_out = ov_out | jnp.any(ovo)
    if not outs:
        heads = jnp.zeros((width, 3), I32)
        valid = jnp.zeros((width,), bool)
    else:
        heads = jnp.concatenate(outs, axis=0)
        valid = jnp.concatenate(vals, axis=0)
        if heads.shape[0] > width:
            heads, valid, sq = _squeeze_stream(heads, valid, target=width)
            ov_squeeze = jnp.any(sq)
        elif heads.shape[0] < width:
            pad = width - heads.shape[0]
            heads = jnp.concatenate([heads, jnp.zeros((pad, 3), I32)])
            valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    return heads, valid, n_deriv, n_appl, ov_bind, ov_out, ov_squeeze


def fused_forward_rounds(
    spo, epoch, marked, tomb, n_used, rep, sort_perm, sorted_keys,
    cands, cand_valid, r0, max_inner,
    atom_consts, head_consts, const_vals, const_valid,
    *,
    plans: tuple,
    rewrite_cap: int,
    bind_cap: int,
    plan_out_cap: int,
    pair_cap: int,
    route_cap: int | None,
    axis: str | None,
    n_shards: int,
    use_kernel: bool,
):
    """The forward round loop as ONE compiled fixpoint.

    Per iteration (identical to one host round): process the candidate
    stream at round ``r`` (normalise, merge rho, sweep, insert), then
    evaluate every delta plan at ``r + 1`` and squeeze the bucketed heads
    back to the carry's stream width.  The loop exits when

    * the stream empties (convergence — the only healthy exit),
    * any capacity flag fires (host raises the matching CapacityError and
      rolls back, so the post-overflow carry is never observed),
    * a contradiction is derived,
    * rho reaches a rule constant (``consts_changed``) — the exit
      iteration's plan evaluation is nullified (``_NULL_ROUND``) and the
      host re-runs it with the rewritten constants, or
    * ``max_inner`` iterations ran (host raises "did not converge").

    Returns ``(spo, epoch, marked, n_used, rep, sort_perm, sorted_keys,
    cands, cand_valid, flags)`` with ``flags`` the exit report (iteration
    count, sticky overflow bits, the exit round's ``n_new``, and the
    accumulated stats deltas).
    """
    width = cands.shape[0]
    assert width == plan_out_cap, (width, plan_out_cap)
    n_res = rep.shape[0]
    false = jnp.zeros((), bool)

    carry = {
        "r": jnp.asarray(r0, I32).reshape(()),
        "iters": jnp.zeros((), I32),
        "spo": spo, "epoch": epoch, "marked": marked, "n_used": n_used,
        "rep": rep, "sort_perm": sort_perm, "sorted_keys": sorted_keys,
        "cands": cands, "cand_valid": cand_valid,
        "have_cands": jnp.ones((), bool),
        "n_new": jnp.zeros((), I32),
        "n_pairs": jnp.zeros((), I32),
        "n_reflexive": jnp.zeros((1,), I32),
        "n_deriv": jnp.zeros((1,), I32),
        "n_appl": jnp.zeros((1,), I32),
        "ov_store": false, "ov_rewrite": false, "ov_route": false,
        "ov_pair": false, "ov_bind": false, "ov_out": false,
        "ov_squeeze": false,
        "contradiction": false, "consts_changed": false,
    }

    def _stop(c):
        return (
            c["ov_store"] | c["ov_rewrite"] | c["ov_route"] | c["ov_pair"]
            | c["ov_bind"] | c["ov_out"] | c["ov_squeeze"]
            | c["contradiction"] | c["consts_changed"]
        )

    def cond(c):
        # the first iteration always runs (the host loop's ``first`` flag:
        # a padded-empty seed stream still needs its convergence round)
        return (c["iters"] == 0) | (
            c["have_cands"] & ~_stop(c) & (c["iters"] < max_inner)
        )

    def body(c):
        r = c["r"] + 1
        (spo_, epoch_, marked_, n_used_, rep_, perm_, keys_, fl) = (
            process_candidates(
                c["spo"], c["epoch"], c["marked"], c["n_used"], c["rep"],
                c["sort_perm"], c["sorted_keys"], c["cands"], c["cand_valid"],
                r, rewrite_cap=rewrite_cap, axis=axis, n_shards=n_shards,
                route_cap=route_cap, pair_cap=pair_cap,
                use_kernel=use_kernel,
            )
        )
        ov_store = _pany(fl["ov_store"], axis)
        ov_rewrite = _pany(fl["ov_rewrite"], axis)
        ov_route = _pany(fl["ov_route"], axis)
        ov_pair = _pany(fl["ov_pair"], axis)
        contradiction = jnp.any(fl["contradiction"])  # already global
        consts_changed = jnp.any(
            const_valid
            & (rep_[jnp.clip(const_vals, 0, n_res - 1)] != const_vals)
        )
        stop = (
            ov_store | ov_rewrite | ov_route | ov_pair
            | contradiction | consts_changed
        )
        n_new = fl["n_new"].reshape(())
        if axis is not None:
            n_new = jax.lax.psum(n_new, axis)

        # plan evaluation for the fresh delta at r + 1; nullified when this
        # iteration is the exit (stats and outputs then contribute zero and
        # the host re-evaluates the round after handling the exit cause)
        r_eval = jnp.where(stop, jnp.asarray(_NULL_ROUND, I32), r + 1)
        heads, valid, n_deriv, n_appl, ov_bind, ov_out, ov_squeeze = (
            _eval_plans(
                spo_, epoch_, marked_, tomb, keys_, perm_, r_eval,
                atom_consts, head_consts, plans, width,
                bind_cap=bind_cap, plan_out_cap=plan_out_cap, axis=axis,
                use_kernel=use_kernel,
            )
        )
        return {
            "r": r, "iters": c["iters"] + 1,
            "spo": spo_, "epoch": epoch_, "marked": marked_,
            "n_used": n_used_, "rep": rep_,
            "sort_perm": perm_, "sorted_keys": keys_,
            "cands": heads, "cand_valid": valid,
            "have_cands": _pany(valid, axis),
            "n_new": n_new,
            "n_pairs": c["n_pairs"] + fl["n_pairs"].reshape(()).astype(I32),
            "n_reflexive": c["n_reflexive"] + fl["n_reflexive"],
            "n_deriv": c["n_deriv"] + n_deriv[None],
            "n_appl": c["n_appl"] + n_appl[None],
            "ov_store": c["ov_store"] | ov_store,
            "ov_rewrite": c["ov_rewrite"] | ov_rewrite,
            "ov_route": c["ov_route"] | ov_route,
            "ov_pair": c["ov_pair"] | ov_pair,
            "ov_bind": c["ov_bind"] | _pany(ov_bind, axis),
            "ov_out": c["ov_out"] | _pany(ov_out, axis),
            "ov_squeeze": c["ov_squeeze"] | _pany(ov_squeeze, axis),
            "contradiction": c["contradiction"] | contradiction,
            "consts_changed": c["consts_changed"] | consts_changed,
        }

    c = jax.lax.while_loop(cond, body, carry)
    flags = {
        k: c[k]
        for k in (
            "iters", "have_cands", "n_new", "n_pairs",
            "n_reflexive", "n_deriv", "n_appl",
            "ov_store", "ov_rewrite", "ov_route", "ov_pair",
            "ov_bind", "ov_out", "ov_squeeze",
            "contradiction", "consts_changed",
        )
    }
    return (
        c["spo"], c["epoch"], c["marked"], c["n_used"], c["rep"],
        c["sort_perm"], c["sorted_keys"], c["cands"], c["cand_valid"], flags,
    )


def fused_delete_waves(
    spo, epoch, marked, tomb, sorted_keys, sort_perm, rep, sizes, suspect,
    max_inner, atom_consts, head_consts,
    *,
    plans: tuple,
    bind_cap: int,
    plan_out_cap: int,
    route_cap: int | None,
    refl_cap: int,
    axis: str | None,
    n_shards: int,
    use_kernel: bool,
):
    """The DRed overdelete wave loop as ONE compiled fixpoint.

    Per iteration (identical to one host wave): evaluate every tombstone
    plan at wave ``w`` against the carry's ``tomb`` column, squeeze the
    bucketed heads to the delta width, and run
    :func:`~repro.core.incremental_spmd._od_step` (mask reduction skipped —
    dead-plan elimination is a host optimisation the fused loop does not
    need).  Exits when a wave tags nothing new, any capacity flag fires, or
    ``max_inner`` waves ran.  The arena columns other than ``tomb`` are
    loop constants — tombstone tagging never changes liveness, so the
    persistent sorted index stays exact for every wave's probes.

    Returns ``(tomb, suspect, flags)``.
    """
    from .incremental_spmd import _od_step  # deferred: module import cycle

    false = jnp.zeros((), bool)
    carry = {
        "w": jnp.zeros((), I32),
        "iters": jnp.zeros((), I32),
        "tomb": tomb, "suspect": suspect,
        "n_od": jnp.zeros((), I32),
        "n_new": jnp.zeros((), I32),
        "ov_route": false, "ov_refl": false,
        "ov_bind": false, "ov_out": false, "ov_squeeze": false,
    }

    def _stop(c):
        return (
            c["ov_route"] | c["ov_refl"] | c["ov_bind"] | c["ov_out"]
            | c["ov_squeeze"]
        )

    def cond(c):
        return (c["iters"] == 0) | (
            (c["n_new"] > 0) & ~_stop(c) & (c["iters"] < max_inner)
        )

    def body(c):
        w = c["w"] + 1
        heads, hv, _nd, _na, ov_bind, ov_out, ov_squeeze = _eval_plans(
            spo, epoch, marked, c["tomb"], sorted_keys, sort_perm, w,
            atom_consts, head_consts, plans, plan_out_cap,
            bind_cap=bind_cap, plan_out_cap=plan_out_cap, axis=axis,
            use_kernel=use_kernel,
        )
        tomb_, suspect_, n_new, ov_route, ov_refl, _masks = _od_step(
            spo, epoch, marked, c["tomb"], sorted_keys, sort_perm, rep,
            sizes, c["suspect"], heads, hv, w,
            axis=axis, n_shards=n_shards, route_cap=route_cap,
            refl_cap=refl_cap, with_masks=False, use_kernel=use_kernel,
        )
        n_new = n_new.reshape(())  # already globally summed by _od_step
        return {
            "w": w, "iters": c["iters"] + 1,
            "tomb": tomb_, "suspect": suspect_,
            "n_od": c["n_od"] + n_new, "n_new": n_new,
            "ov_route": c["ov_route"] | _pany(ov_route, axis),
            "ov_refl": c["ov_refl"] | _pany(ov_refl, axis),
            "ov_bind": c["ov_bind"] | _pany(ov_bind, axis),
            "ov_out": c["ov_out"] | _pany(ov_out, axis),
            "ov_squeeze": c["ov_squeeze"] | _pany(ov_squeeze, axis),
        }

    c = jax.lax.while_loop(cond, body, carry)
    flags = {
        k: c[k]
        for k in (
            "iters", "n_od", "n_new",
            "ov_route", "ov_refl", "ov_bind", "ov_out", "ov_squeeze",
        )
    }
    return c["tomb"], c["suspect"], flags


# -- audit trace builders (repro.analysis) ----------------------------------
#
# The fused fns join the inventory like every other hot compiled fn: traced
# single-device, un-jitted, at the caller's probe geometry.  ``fforward``
# carries no exemptions — the while body's sorts are all delta/bind width
# and its scatters delta width.  ``fwave`` inlines ``_od_step``, whose
# per-``n_res`` mask reductions scatter arena-length update streams by
# design (the od family's documented exemption).

def _audit_tables(engine, state):
    from .engine_jax import I32 as _I32  # noqa: F401 (symmetry with peers)

    return program_tables(state.program)


@register_auditable("fforward")
def _audit_fforward(engine, state):
    width = engine.delta_out
    ac, hc, cv, cvd = _audit_tables(engine, state)
    fn = partial(
        fused_forward_rounds,
        plans=forward_plan_signature(state.program),
        rewrite_cap=engine.delta_rewrite, bind_cap=engine.delta_bind,
        plan_out_cap=width, pair_cap=engine.pair_cap, route_cap=None,
        axis=None, n_shards=1, use_kernel=engine.use_kernel,
    )
    jx = jax.make_jaxpr(fn)(
        state.spo, state.epoch, state.marked, state.tomb, state.n_used,
        state.rep, state.sort_perm, state.sorted_keys,
        jnp.zeros((width, 3), I32), jnp.zeros((width,), bool),
        jnp.asarray(1, I32), jnp.asarray(64, I32), ac, hc, cv, cvd,
    )
    yield "fforward", jx


@register_auditable("fwave", skip_passes=("NoArenaScatter",))
def _audit_fwave(engine, state):
    width = engine.delta_out
    ac, hc, _cv, _cvd = _audit_tables(engine, state)
    fn = partial(
        fused_delete_waves,
        plans=forward_plan_signature(state.program, tombstone=True),
        bind_cap=engine.delta_bind, plan_out_cap=width, route_cap=None,
        refl_cap=width, axis=None, n_shards=1, use_kernel=engine.use_kernel,
    )
    jx = jax.make_jaxpr(fn)(
        state.spo, state.epoch, state.marked, state.tomb,
        state.sorted_keys, state.sort_perm, state.rep,
        jnp.zeros((state.n_res,), I32), jnp.zeros((state.n_res,), bool),
        jnp.asarray(64, I32), ac, hc,
    )
    yield "fwave", jx
