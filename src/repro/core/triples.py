"""Fixed-growth triple arena with a validity bitmask.

The paper never deletes facts — it *marks* them outdated and skips them during
matching, removing marked facts in postprocessing (§4).  The arena mirrors
that: rows are append-only; ``valid`` flips to False when a fact is rewritten.
Join machinery indexes only valid rows via sorted int64 keys (21 bits per
position), the SIMD-friendly replacement for RDFox's six hash/array indexes.
"""

from __future__ import annotations

import numpy as np

_SHIFT_S = 42
_SHIFT_P = 21


def pack(spo: np.ndarray) -> np.ndarray:
    """(n,3) int -> (n,) int64 lexicographic sort key."""
    s = spo[:, 0].astype(np.int64)
    p = spo[:, 1].astype(np.int64)
    o = spo[:, 2].astype(np.int64)
    return (s << _SHIFT_S) | (p << _SHIFT_P) | o


def dedup_rows(spo: np.ndarray) -> np.ndarray:
    """Distinct triples of an (n, 3) batch, first occurrence order kept."""
    spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
    if spo.shape[0] == 0:
        return spo
    _, idx = np.unique(pack(spo), return_index=True)
    return spo[np.sort(idx)]


def setdiff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of ``a`` whose packed key is not in ``b`` (both (n, 3))."""
    if a.shape[0] == 0 or b.shape[0] == 0:
        return a
    return a[~np.isin(pack(a), pack(b))]


def unpack(keys: np.ndarray) -> np.ndarray:
    mask = (1 << 21) - 1
    s = (keys >> _SHIFT_S) & mask
    p = (keys >> _SHIFT_P) & mask
    o = keys & mask
    return np.stack([s, p, o], axis=1).astype(np.int32)


def apply_op(explicit: np.ndarray, op: str, delta: np.ndarray) -> np.ndarray:
    """Apply an ``("add" | "delete", delta)`` event to an explicit fact set.

    Packed-set algebra returning the sorted distinct explicit set a
    from-scratch run would start from — the oracle-side bookkeeping shared
    by the incremental tests and bench_incremental.
    """
    explicit = np.asarray(explicit, np.int32).reshape(-1, 3)
    delta = np.asarray(delta, np.int32).reshape(-1, 3)
    cur = set(pack(explicit).tolist())
    d = set(pack(delta).tolist())
    cur = (cur | d) if op == "add" else (cur - d)
    keys = np.asarray(sorted(cur), dtype=np.int64)
    return unpack(keys) if keys.shape[0] else np.zeros((0, 3), np.int32)


class TripleArena:
    """Append-only store with outdated-marking, mirroring T in the paper."""

    def __init__(self, capacity: int = 1024) -> None:
        self.spo = np.zeros((capacity, 3), dtype=np.int32)
        self.valid = np.zeros(capacity, dtype=bool)
        self.n = 0
        # membership set over *valid* rows: sorted packed keys + row perm
        self._keys: np.ndarray | None = None
        self._rows: np.ndarray | None = None

    # -- capacity ----------------------------------------------------------
    def _ensure(self, extra: int) -> None:
        need = self.n + extra
        cap = self.spo.shape[0]
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        spo = np.zeros((cap, 3), dtype=np.int32)
        spo[: self.n] = self.spo[: self.n]
        valid = np.zeros(cap, dtype=bool)
        valid[: self.n] = self.valid[: self.n]
        self.spo, self.valid = spo, valid

    # -- index -------------------------------------------------------------
    def _rebuild_index(self) -> None:
        rows = np.flatnonzero(self.valid[: self.n])
        keys = pack(self.spo[rows])
        order = np.argsort(keys, kind="stable")
        self._keys = keys[order]
        self._rows = rows[order]

    def index(self) -> tuple[np.ndarray, np.ndarray]:
        if self._keys is None:
            self._rebuild_index()
        return self._keys, self._rows  # type: ignore[return-value]

    # -- core ops ----------------------------------------------------------
    def contains(self, spo: np.ndarray) -> np.ndarray:
        """Boolean membership of candidate triples among *valid* rows."""
        keys, _ = self.index()
        cand = pack(np.asarray(spo, dtype=np.int32).reshape(-1, 3))
        pos = np.searchsorted(keys, cand)
        pos = np.clip(pos, 0, keys.shape[0] - 1) if keys.shape[0] else pos
        if keys.shape[0] == 0:
            return np.zeros(cand.shape[0], dtype=bool)
        return keys[pos] == cand

    def add_batch(self, spo: np.ndarray) -> np.ndarray:
        """T.add for a batch: dedup within the batch and against valid rows.

        Returns the (m,3) array of facts actually added (the new Delta).
        The membership index is maintained incrementally — a sorted merge of
        the few new keys instead of a full O(n log n) re-sort, which is what
        makes small incremental updates cheap against a large store.
        """
        spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
        if spo.shape[0] == 0:
            return spo
        keys = pack(spo)
        uniq_keys, first = np.unique(keys, return_index=True)
        cand = spo[np.sort(first)]
        fresh = cand[~self.contains(cand)]
        if fresh.shape[0] == 0:
            return fresh
        self._ensure(fresh.shape[0])
        rows = np.arange(self.n, self.n + fresh.shape[0])
        self.spo[rows] = fresh
        self.valid[rows] = True
        self.n += fresh.shape[0]
        if self._keys is not None:
            fk = pack(fresh)
            order = np.argsort(fk, kind="stable")
            pos = np.searchsorted(self._keys, fk[order])
            self._keys = np.insert(self._keys, pos, fk[order])
            self._rows = np.insert(self._rows, pos, rows[order])
        return fresh

    def mark_rows(self, rows: np.ndarray) -> None:
        """T.mark: flip validity (facts stay in the arena, as in the paper)."""
        rows = np.asarray(rows).reshape(-1)
        if rows.shape[0] and self._keys is not None:
            live = rows[self.valid[rows]]
            if live.shape[0]:
                keys = np.sort(pack(self.spo[live]))
                pos = np.searchsorted(self._keys, keys)
                self._keys = np.delete(self._keys, pos)
                self._rows = np.delete(self._rows, pos)
        self.valid[rows] = False

    def rows_of(self, facts: np.ndarray) -> np.ndarray:
        """Arena row indices of *valid* rows whose triple is in ``facts``."""
        if facts.shape[0] == 0:
            return np.zeros(0, dtype=np.int64)
        keys, rows = self.index()
        if keys.shape[0] == 0:
            return np.zeros(0, dtype=np.int64)
        cand = np.unique(pack(facts))
        pos = np.searchsorted(keys, cand)
        pos = np.clip(pos, 0, keys.shape[0] - 1)
        hit = keys[pos] == cand
        return rows[pos[hit]]

    def valid_triples(self) -> np.ndarray:
        return self.spo[: self.n][self.valid[: self.n]]

    def rewrite_sweep(self, rep: np.ndarray) -> np.ndarray:
        """Bulk analogue of Algorithm 3: mark outdated rows, return rewrites.

        A row is outdated iff any position changes under rho.  Returns the
        rewritten versions (not yet inserted; caller routes them through
        ``add_batch`` so re-derivations dedup correctly).
        """
        live = self.spo[: self.n]
        mask_valid = self.valid[: self.n]
        rewritten = rep[live]
        changed = (rewritten != live).any(axis=1) & mask_valid
        rows = np.flatnonzero(changed)
        if rows.shape[0] == 0:
            return np.zeros((0, 3), dtype=np.int32)
        self.mark_rows(rows)
        return rewritten[rows].astype(np.int32)

    # -- stats -------------------------------------------------------------
    @property
    def total(self) -> int:
        return self.n

    @property
    def unmarked(self) -> int:
        return int(self.valid[: self.n].sum())

    @property
    def nbytes(self) -> int:
        return self.spo.nbytes + self.valid.nbytes
