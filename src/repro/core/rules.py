"""Datalog rule IR and parsing.

A rule is ``head <- body`` where the head is one atom and the body a
conjunction of atoms; an atom is an int32 triple where positive entries are
resource IDs and negative entries are variables (see :mod:`repro.core.terms`).
Rules correspond to SWRL / DL-style OWL 2 RL rules (paper §2).

The paper's key correctness point is that rules must be rewritten alongside
facts: ``rho(rule)`` replaces every *constant* with its representative
(variables are untouched).  ``Program.rewrite`` returns the rewritten program
plus the set of rules that actually changed (the paper's queue ``R``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

from .terms import Dictionary, is_var

Atom = tuple[int, int, int]


@dataclass(frozen=True)
class Rule:
    head: Atom
    body: tuple[Atom, ...]

    def __post_init__(self) -> None:
        body_vars = {t for atom in self.body for t in atom if is_var(t)}
        head_vars = {t for t in self.head if is_var(t)}
        if not head_vars <= body_vars:
            raise ValueError(f"unsafe rule: head vars {head_vars - body_vars} not in body")

    @property
    def variables(self) -> tuple[int, ...]:
        seen: list[int] = []
        for atom in self.body:
            for t in atom:
                if is_var(t) and t not in seen:
                    seen.append(t)
        return tuple(seen)

    def constants(self) -> set[int]:
        out = set()
        for atom in (self.head, *self.body):
            for t in atom:
                if not is_var(t):
                    out.add(t)
        return out

    def rewrite(self, rep: np.ndarray) -> "Rule":
        """rho(rule): map every constant through the representative array."""

        def rw(atom: Atom) -> Atom:
            return tuple(int(rep[t]) if t >= 0 else t for t in atom)  # type: ignore[return-value]

        return Rule(rw(self.head), tuple(rw(a) for a in self.body))


class Program:
    """An ordered set of rules with identity-preserving rewriting."""

    def __init__(self, rules: list[Rule]) -> None:
        self.rules = list(rules)

    def __len__(self) -> int:
        return len(self.rules)

    def __iter__(self):
        return iter(self.rules)

    def constants(self) -> set[int]:
        out: set[int] = set()
        for r in self.rules:
            out |= r.constants()
        return out

    def rewrite(self, rep: np.ndarray) -> tuple["Program", list[int]]:
        """Return (rho(P), indices of rules that changed).

        Mirrors Algorithm 1 lines 6-9: the changed rules are the ones queued
        for re-evaluation against the full store.
        """
        new_rules: list[Rule] = []
        changed: list[int] = []
        for i, r in enumerate(self.rules):
            rr = r.rewrite(rep)
            new_rules.append(rr)
            if rr != r:
                changed.append(i)
        return Program(new_rules), changed


_ATOM_RE = re.compile(r"\(\s*([^,()\s]+)\s*,\s*([^,()\s]+)\s*,\s*([^,()\s]+)\s*\)")


def parse_term(tok: str, dic: Dictionary, varmap: dict[str, int]) -> int:
    if tok.startswith("?"):
        if tok not in varmap:
            varmap[tok] = -(len(varmap) + 1)
        return varmap[tok]
    return dic.intern(tok)


def parse_rule(text: str, dic: Dictionary) -> Rule:
    """Parse ``(h) <- (b1) & (b2) ...`` with ``?x`` variables.

    Example: ``(?x, owl:sameAs, :USA) <- (:Obama, :presidentOf, ?x)``
    """
    head_txt, _, body_txt = text.partition("<-")
    varmap: dict[str, int] = {}
    heads = _ATOM_RE.findall(head_txt)
    if len(heads) != 1:
        raise ValueError(f"expected exactly one head atom in {text!r}")
    head = tuple(parse_term(t, dic, varmap) for t in heads[0])
    body = tuple(
        tuple(parse_term(t, dic, varmap) for t in m) for m in _ATOM_RE.findall(body_txt)
    )
    if not body:
        raise ValueError(f"rule with empty body: {text!r}")
    return Rule(head, body)  # type: ignore[arg-type]


def parse_program(lines: list[str] | str, dic: Dictionary) -> Program:
    if isinstance(lines, str):
        lines = [ln for ln in lines.splitlines()]
    rules = []
    for ln in lines:
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        rules.append(parse_rule(ln, dic))
    return Program(rules)


def parse_facts(lines: list[str] | str, dic: Dictionary) -> np.ndarray:
    """Parse ``(s, p, o)`` fact lines into an (n, 3) int32 array."""
    if isinstance(lines, str):
        lines = [ln for ln in lines.splitlines()]
    out = []
    for ln in lines:
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        m = _ATOM_RE.findall(ln)
        if len(m) != 1:
            raise ValueError(f"expected one fact per line: {ln!r}")
        trip = tuple(dic.intern(t) for t in m[0])
        out.append(trip)
    return np.asarray(out, dtype=np.int32).reshape(-1, 3)
