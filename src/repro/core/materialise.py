"""Materialisation drivers: AX (explicit axiomatisation) and REW (rewriting).

``materialise_ax``   computes [P u P~=]^inf(E) with the paper's rules ~=1..~=5
                     added as ordinary datalog rules (the baseline the paper
                     compares against, §3/§6 'AX mode').
``materialise_rew``  is the paper's contribution (§4): maintain rho, rewrite
                     facts *and rules*, mark-don't-delete, re-evaluate
                     rewritten rules, add reflexive sameAs facts — adapted to
                     bulk-synchronous rounds (DESIGN.md §2).

``expand``           computes T^rho (the expansion) — used by tests as the
                     Theorem 1(3) oracle: expand(REW result) == AX result.
"""

from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from .axiom import is_contradiction, with_axiomatisation
from .rules import Program
from .seminaive import eval_rule_delta, eval_rule_full
from .stats import MatStats
from .terms import DIFFERENT_FROM, SAME_AS
from .triples import TripleArena, dedup_rows as _dedup, pack
from .uf import clique_members, compress_np, merge_pairs_np


class Contradiction(Exception):
    """Rule ~=5 fired: <a, owl:differentFrom, a>."""


@dataclass
class MatResult:
    arena: TripleArena
    rep: np.ndarray
    program: Program          # final (possibly rewritten) program
    stats: MatStats
    dictionary: object = None
    deriv_counter: Counter | None = None  # packed-fact -> times derived

    def triples(self) -> np.ndarray:
        return self.arena.valid_triples()

    def clique_sizes_of(self, ids: np.ndarray) -> np.ndarray:
        from .uf import clique_sizes

        sizes = clique_sizes(self.rep)
        return sizes[self.rep[ids]]


def _check_contradictions(cands: np.ndarray) -> None:
    bad = (cands[:, 1] == DIFFERENT_FROM) & (cands[:, 0] == cands[:, 2])
    if bad.any():
        row = cands[np.flatnonzero(bad)[0]]
        raise Contradiction(f"<{row[0]}, owl:differentFrom, {row[0]}> derived")


# ---------------------------------------------------------------------------
# AX mode
# ---------------------------------------------------------------------------

def materialise_ax(
    facts: np.ndarray,
    program: Program,
    n_resources: int,
    max_rounds: int = 10_000,
    track_derivations: bool = False,
) -> MatResult:
    t0 = time.perf_counter()
    stats = MatStats(mode="AX")
    counter: Counter | None = Counter() if track_derivations else None
    arena = TripleArena()
    p_ax = with_axiomatisation(program)

    cands = np.asarray(facts, dtype=np.int32).reshape(-1, 3)
    stats.triples_explicit = cands.shape[0]
    while cands.shape[0] > 0:
        _check_contradictions(cands)
        delta = arena.add_batch(cands)
        if delta.shape[0] == 0:
            break
        stats.rounds += 1
        if stats.rounds > max_rounds:
            raise RuntimeError("materialisation did not converge")
        live = arena.spo[: arena.n][arena.valid[: arena.n]]
        # rows are append-only, so the trailing delta rows are the new ones
        t_old = live[: live.shape[0] - delta.shape[0]]
        t_all = live
        outs = []
        for rule in p_ax:
            h, nd, na = eval_rule_delta(rule, t_old, t_all, delta)
            stats.derivations += nd
            stats.rule_applications += na
            if counter is not None and h.shape[0]:
                counter.update(pack(h).tolist())
            outs.append(h)
        cands = _dedup(np.concatenate(outs, axis=0)) if outs else np.zeros((0, 3), np.int32)

    stats.triples_total = arena.total
    stats.triples_unmarked = arena.unmarked
    stats.memory_bytes = arena.nbytes
    stats.wall_seconds = time.perf_counter() - t0
    rep = np.arange(n_resources, dtype=np.int32)
    return MatResult(arena, rep, p_ax, stats, deriv_counter=counter)


# ---------------------------------------------------------------------------
# REW mode (the paper's algorithm, bulk-synchronous)
# ---------------------------------------------------------------------------

def rew_rounds(
    arena: TripleArena,
    rep: np.ndarray,
    program: Program,
    cands: np.ndarray,
    stats: MatStats,
    max_rounds: int = 10_000,
    r_queue: list | None = None,
) -> tuple[np.ndarray, Program]:
    """Run the bulk-synchronous REW loop to fixpoint over ``cands``.

    The shared driver behind :func:`materialise_rew` (which starts from an
    empty arena) and :mod:`repro.core.incremental` (which resumes from a
    populated arena: additions seed ``cands`` with the new triples, deletions
    seed it with the rederivation candidates after the B/F overdelete pass).
    Mutates ``arena`` and ``stats`` in place; returns the updated
    ``(rep, program)``.  ``max_rounds`` bounds this invocation, not the
    cumulative ``stats.rounds``.
    """
    p_cur = program
    r_queue = list(r_queue) if r_queue else []  # rules awaiting full re-eval
    cands = np.asarray(cands, dtype=np.int32).reshape(-1, 3)
    rounds_here = 0

    while cands.shape[0] > 0 or r_queue:
        stats.rounds += 1
        rounds_here += 1
        if rounds_here > max_rounds:
            raise RuntimeError("materialisation did not converge")

        # ---- process candidates (Algorithm 4, batched) -------------------
        cands = rep[cands].astype(np.int32) if cands.shape[0] else cands

        sameas = (cands[:, 1] == SAME_AS) if cands.shape[0] else np.zeros(0, bool)
        nontriv = sameas & (cands[:, 0] != cands[:, 2])
        pairs = cands[nontriv][:, [0, 2]]
        rep_changed = False
        if pairs.shape[0]:
            pairs = np.unique(pairs, axis=0)
            stats.sameas_pairs += pairs.shape[0]
            rep, n_merged = merge_pairs_np(rep, pairs)
            if n_merged:
                rep_changed = True
                stats.merged_resources += n_merged

        if rep_changed:
            # re-normalise candidates under the new rho, then sweep the arena
            # (bulk Algorithm 3: mark outdated facts, re-derive their rewriting)
            cands = rep[cands].astype(np.int32)
            rewritten = arena.rewrite_sweep(rep)
        else:
            rewritten = np.zeros((0, 3), np.int32)

        # non-sameAs-pair candidates (pairs became reflexive under new rho)
        to_store = _dedup(np.concatenate([cands, rewritten], axis=0))
        # ~=5 must see the post-merge normal forms: <a,dF,b> with a,b merged
        # is a contradiction even though neither raw candidate was reflexive
        _check_contradictions(to_store)
        delta = arena.add_batch(to_store)

        # reflexivity (Algorithm 4 lines 17-18): <c, sameAs, c> for every
        # resource of every stored fact; chases its own closure through ~=.
        if delta.shape[0]:
            res = np.unique(delta)
            res = np.unique(np.concatenate([res, [SAME_AS]]))
            refl = np.stack(
                [res, np.full_like(res, SAME_AS), res], axis=1
            ).astype(np.int32)
            refl_added = arena.add_batch(refl)
            stats.reflexive_added += refl_added.shape[0]
            stats.derivations += refl_added.shape[0]
            delta = np.concatenate([delta, refl_added], axis=0)

        # ---- rule rewriting barrier (Algorithm 1 lines 6-11) -------------
        if rep_changed:
            p_new, changed_idx = p_cur.rewrite(rep)
            if changed_idx:
                stats.rule_rewrites += 1
                stats.rules_requeued += len(changed_idx)
                r_queue.extend(p_new.rules[i] for i in changed_idx)
            p_cur = p_new

        # ---- evaluate rules on the new delta ------------------------------
        live = arena.spo[: arena.n][arena.valid[: arena.n]]
        t_all = live
        t_old = live[: live.shape[0] - delta.shape[0]]
        outs = []
        for rule in p_cur:
            h, nd, na = eval_rule_delta(rule, t_old, t_all, delta)
            stats.derivations += nd
            stats.rule_applications += na
            outs.append(h)
        for rule in r_queue:
            h, nd, na = eval_rule_full(rule, t_all)
            stats.derivations += nd
            stats.rule_applications += na
            outs.append(h)
        r_queue = []
        cands = _dedup(np.concatenate(outs, axis=0)) if outs else np.zeros((0, 3), np.int32)
        # drop candidates already present (cheap pre-filter; add_batch rededups)
        if cands.shape[0]:
            cands = cands[~arena.contains(rep[cands].astype(np.int32))]

    return compress_np(rep), p_cur


def materialise_rew(
    facts: np.ndarray,
    program: Program,
    n_resources: int,
    max_rounds: int = 10_000,
) -> MatResult:
    t0 = time.perf_counter()
    stats = MatStats(mode="REW")
    arena = TripleArena()
    rep = np.arange(n_resources, dtype=np.int32)

    cands = np.asarray(facts, dtype=np.int32).reshape(-1, 3)
    stats.triples_explicit = cands.shape[0]
    rep, p_cur = rew_rounds(arena, rep, program, cands, stats, max_rounds)

    stats.triples_total = arena.total
    stats.triples_unmarked = arena.unmarked
    stats.memory_bytes = arena.nbytes
    stats.wall_seconds = time.perf_counter() - t0
    return MatResult(arena, rep, p_cur, stats)


def materialise(facts, program, n_resources, mode: str = "REW", **kw) -> MatResult:
    if mode.upper() == "AX":
        return materialise_ax(facts, program, n_resources, **kw)
    if mode.upper() == "REW":
        return materialise_rew(facts, program, n_resources, **kw)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# expansion + Theorem 1 validators
# ---------------------------------------------------------------------------

def expand(triples: np.ndarray, rep: np.ndarray) -> set[tuple[int, int, int]]:
    """T^rho = { <s,p,o> | <rho(s),rho(p),rho(o)> in T } as an explicit set.

    Only usable at test scale — the whole point of the paper is to avoid ever
    materialising this set.
    """
    rep = compress_np(rep)
    members = clique_members(rep)

    def mem(r: int) -> np.ndarray:
        return members.get(int(r), np.array([r], dtype=np.int64))

    out: set[tuple[int, int, int]] = set()
    for s, p, o in np.asarray(triples):
        ms, mp, mo = mem(s), mem(p), mem(o)
        for a in ms:
            for b in mp:
                for c in mo:
                    out.add((int(a), int(b), int(c)))
    return out


def check_theorem1(res: MatResult, ax: MatResult | None = None) -> None:
    """Assert the three properties of Theorem 1 (raises AssertionError)."""
    t = res.triples()
    # (1) rho captures all equalities: no unmarked non-reflexive sameAs fact
    sa = t[(t[:, 1] == SAME_AS)]
    assert (sa[:, 0] == sa[:, 2]).all(), "non-reflexive sameAs fact survived"
    # (2) T is minimal: every unmarked fact is rho-normal
    assert (res.rep[t] == t).all(), "fact with outdated resource survived"
    # (3) T^rho == [P u P~=]^inf(E)
    if ax is not None:
        lhs = expand(t, res.rep)
        rhs = {tuple(map(int, row)) for row in ax.triples()}
        assert lhs == rhs, (
            f"expansion mismatch: only-rew={len(lhs - rhs)} only-ax={len(rhs - lhs)}"
        )
