"""Incremental materialisation maintenance for the REW (rewriting) mode.

The source paper (arXiv:1411.3622) materialises once; its successor —
Motik et al., *Combining Rewriting and Incremental Materialisation
Maintenance for Datalog Programs with Equality* (arXiv:1505.00212) — extends
the same rewriting machinery to fact addition and deletion without a
from-scratch rerun.  This module is the bulk-synchronous adaptation of that
algorithm on top of the existing engine pieces:

``add_facts``
    Additions are the easy direction: the semi-naive delta discipline of
    :func:`repro.core.materialise.rew_rounds` is *restartable* — seeding the
    round loop with the new explicit triples considers exactly the
    substitutions that involve at least one new fact (old-only substitutions
    were found by the base run), so the existing loop is reused verbatim,
    including rho maintenance, the Algorithm-3 sweep and rule rewriting.

``delete_facts``
    Deletions use a rewriting-aware Backward/Forward (B/F-style) pass:

    1. **Overdelete** (DRed backward step, batched): starting from the
       rho-normal forms of the deleted triples, repeatedly evaluate the
       current program's delta plans with Delta = the overdeleted frontier
       and all other atoms against the *pre-deletion* store, and overdelete
       every stored fact the derived heads normalise onto.
    2. **Overdelete reflexivity children**: a ``<c, sameAs, c>`` fact has
       its genesis in the facts that mention ``c``, so when such a fact is
       overdeleted its resources' reflexive witnesses are overdeleted too.
       This is deliberately over-approximate — a model-based "is there
       surviving support" check is unsound under the refl-row -> rule-head
       cycles that equality programs produce; DRed soundness needs the full
       may-be-affected cone, with survivors restored in step 4.
    3. **Split sameAs cliques**: a clique is *suspect* iff its reflexive
       witness ``<r, sameAs, r>`` was overdeleted — every derivation of an
       equality between members normalises onto that witness, so an intact
       witness proves no merge lost support.  Suspect cliques are split by
       resetting their members to singletons (the inverse of min-hooking;
       re-merging below goes through the same
       :func:`repro.core.uf.merge_pairs_np` machinery), and every stored
       fact touching a suspect representative is overdeleted too — a stored
       normal form conflates clique members, so after a split it cannot be
       trusted until rederived.
    Steps 1-3 iterate to a joint fixpoint (each can enable the others).
    4. **Rederive + forward**: the rules are re-rewritten from the *base*
       program under the split rho, and three candidate families are seeded
       back into :func:`rew_rounds`: every still-explicit triple whose
       normal form went missing, every head derivable in one step from the
       surviving store, and the reflexive witnesses of resources that still
       occur in surviving facts.  The loop re-merges whatever equalities
       still hold and re-rewrites affected triples through the normal
       Algorithm-3 sweep.

    Correctness oracle (tests/test_incremental.py): the incremental result
    must equal the from-scratch REW materialisation of the updated explicit
    set — same rho, same normal-form store, same Theorem-1 expansion.

Normal forms of large batches can be computed through the Pallas kernel
:func:`repro.kernels.rewrite_triples.rewrite_triples` (``use_kernel=True``;
interpret mode off-TPU) — the same kernel the TPU engine uses for its sweep —
or through plain numpy gathers (the default at CPU test scale).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .materialise import MatResult, rew_rounds
from .rules import Program, Rule
from .seminaive import _const_filter, eval_rule_delta, eval_rule_full
from .stats import MatStats
from .terms import SAME_AS, is_var
from .triples import TripleArena, dedup_rows, pack, setdiff_rows
from .uf import clique_sizes, split_cliques

__all__ = [
    "IncrementalState",
    "materialise_incremental",
    "add_facts",
    "delete_facts",
    "normal_forms",
]


def normal_forms(
    spo: np.ndarray, rep: np.ndarray, use_kernel: bool = False
) -> np.ndarray:
    """``rho[spo]`` for an (n, 3) batch; optionally on the Pallas kernel."""
    spo = np.asarray(spo, dtype=np.int32).reshape(-1, 3)
    if spo.shape[0] == 0:
        return spo
    if use_kernel:
        import jax.numpy as jnp

        from repro.kernels.rewrite_triples import rewrite_triples

        out, _changed = rewrite_triples(
            jnp.asarray(spo, jnp.int32), jnp.asarray(rep, jnp.int32)
        )
        return np.asarray(out, dtype=np.int32)
    return rep[spo].astype(np.int32)


@dataclass
class IncrementalState:
    """A materialised store that supports add/delete maintenance.

    ``rep`` is always fully compressed; ``program`` is the current rewritten
    program rho(``base_program``); ``explicit`` is the current explicit fact
    set in *original* resource IDs (the set a from-scratch run would start
    from); ``stats`` accumulates across the base run and every update.
    """

    arena: TripleArena
    rep: np.ndarray
    program: Program
    base_program: Program
    explicit: np.ndarray
    n_resources: int
    stats: MatStats = field(default_factory=lambda: MatStats(mode="REW-inc"))
    use_kernel: bool = False

    def result(self) -> MatResult:
        self.stats.triples_total = self.arena.total
        self.stats.triples_unmarked = self.arena.unmarked
        self.stats.memory_bytes = self.arena.nbytes
        return MatResult(self.arena, self.rep, self.program, self.stats)

    def triples(self) -> np.ndarray:
        return self.arena.valid_triples()

    # -- internal ------------------------------------------------------------
    def _grow_rep(self, facts: np.ndarray) -> None:
        """Extend rho with identity entries for unseen resource IDs."""
        if facts.shape[0] == 0:
            return
        hi = int(facts.max()) + 1
        if hi > self.rep.shape[0]:
            ext = np.arange(self.rep.shape[0], hi, dtype=self.rep.dtype)
            self.rep = np.concatenate([self.rep, ext])
            self.n_resources = hi


def materialise_incremental(
    facts: np.ndarray,
    program: Program,
    n_resources: int,
    max_rounds: int = 10_000,
    use_kernel: bool = False,
) -> IncrementalState:
    """From-scratch REW materialisation that returns a maintainable state."""
    t0 = time.perf_counter()
    stats = MatStats(mode="REW-inc")
    arena = TripleArena()
    rep = np.arange(n_resources, dtype=np.int32)
    facts = dedup_rows(facts)
    stats.triples_explicit = facts.shape[0]
    rep, p_cur = rew_rounds(arena, rep, program, facts, stats, max_rounds)
    stats.wall_seconds += time.perf_counter() - t0
    return IncrementalState(
        arena=arena,
        rep=rep,
        program=p_cur,
        base_program=program,
        explicit=facts,
        n_resources=n_resources,
        stats=stats,
        use_kernel=use_kernel,
    )


def add_facts(
    state: IncrementalState, delta: np.ndarray, max_rounds: int = 10_000
) -> IncrementalState:
    """Add explicit triples and maintain the materialisation in place.

    Seeds the shared round loop with the fresh triples: the delta-plan
    discipline guarantees every substitution involving at least one new fact
    is considered exactly once, and old-only substitutions were exhausted by
    the base run.  May raise :class:`repro.core.materialise.Contradiction`
    (rule ~=5), in which case the state is left partially updated and should
    be discarded, exactly like a failed from-scratch run.
    """
    t0 = time.perf_counter()
    delta = dedup_rows(delta)
    delta = setdiff_rows(delta, state.explicit)
    if delta.shape[0] == 0:
        state.stats.wall_seconds += time.perf_counter() - t0
        return state
    state._grow_rep(delta)
    state.explicit = np.concatenate([state.explicit, delta], axis=0)
    state.stats.triples_explicit = state.explicit.shape[0]
    state.rep, state.program = rew_rounds(
        state.arena, state.rep, state.program, delta, state.stats, max_rounds
    )
    state.stats.wall_seconds += time.perf_counter() - t0
    return state


# ---------------------------------------------------------------------------
# deletion: B/F-style overdelete + clique split + rederive
# ---------------------------------------------------------------------------

def _rule_touches(rule: Rule, f_spo: np.ndarray) -> bool:
    """True iff some frontier fact matches some body atom's constant
    pattern — a rule none of whose atoms can bind a frontier fact cannot
    contribute to the overdeletion wave, so its delta plans are skipped."""
    for atom in rule.body:
        if _const_filter(atom, f_spo).any():
            return True
    return False


def _rule_may_rederive(rule: Rule, o_spo: np.ndarray, rep_old: np.ndarray) -> bool:
    """False iff no overdeleted fact can match the rule's head pattern.

    Rederivation only ever needs to restore *overdeleted* facts (everything
    else either survived in the store or requires a new fact to derive), so
    rules whose head constants are incompatible with every overdeleted
    normal form are skipped.  Constants are collapsed through the
    pre-deletion rho because ``o_spo`` rows are normal under it while the
    rule was rewritten under the post-split rho.
    """
    if o_spo.shape[0] == 0:
        return False
    mask = np.ones(o_spo.shape[0], dtype=bool)
    for pos, t in enumerate(rule.head):
        if not is_var(t):
            mask &= o_spo[:, pos] == rep_old[t]
    return bool(mask.any())


def _overdelete(
    state: IncrementalState, deleted: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """The backward half of the B/F pass.

    Returns ``(overdel_rows, suspect_reps)``: the arena row indices to
    retract and the representatives of the sameAs cliques that must be
    split.  Pure analysis — the arena is not modified here.
    """
    arena, rep = state.arena, state.rep
    n = arena.n
    valid = arena.valid[:n]
    spo_all = arena.spo[:n]
    t_snapshot = spo_all[valid]  # pre-deletion store (DRed matches against T)

    overdel = np.zeros(n, dtype=bool)
    suspect = np.zeros(rep.shape[0], dtype=bool)
    sizes = clique_sizes(rep)

    # seed: normal forms of the deleted explicit triples
    frontier = arena.rows_of(normal_forms(deleted, rep, state.use_kernel))
    overdel[frontier] = True

    while frontier.shape[0]:
        # 1) backward rule closure: heads derivable with >= 1 body atom in
        # the frontier and the rest anywhere in the pre-deletion store
        f_spo = spo_all[frontier]
        outs = []
        for rule in state.program:
            if not _rule_touches(rule, f_spo):
                continue
            h, _nd, _na = eval_rule_delta(rule, t_snapshot, t_snapshot, f_spo)
            if h.shape[0]:
                outs.append(h)
        heads = (
            np.concatenate(outs, axis=0)
            if outs
            else np.zeros((0, 3), np.int32)
        )
        heads = normal_forms(heads, rep, state.use_kernel)

        new_rows = arena.rows_of(heads)
        new_rows = new_rows[~overdel[new_rows]]

        # 2) reflexivity children: <c, sameAs, c> for every resource of this
        # wave may have lost its genesis — overdelete, rederive survivors
        res = np.unique(np.append(np.unique(f_spo), SAME_AS))
        refl = np.stack(
            [res, np.full_like(res, SAME_AS), res], axis=1
        ).astype(np.int32)
        refl_rows = arena.rows_of(refl)
        refl_rows = refl_rows[~overdel[refl_rows]]
        new_rows = np.concatenate([new_rows, refl_rows])

        # 3) suspect cliques: the reflexive witness <r, sameAs, r> of a
        # multi-member clique was overdeleted -> split required, and every
        # stored fact touching r is no longer trustworthy
        wit = np.concatenate([frontier, new_rows])
        wit_spo = spo_all[wit]
        is_wit = (
            (wit_spo[:, 1] == SAME_AS)
            & (wit_spo[:, 0] == wit_spo[:, 2])
            & (sizes[wit_spo[:, 0]] > 1)
        )
        fresh_sus = np.unique(wit_spo[is_wit][:, 0])
        fresh_sus = fresh_sus[~suspect[fresh_sus]]
        if fresh_sus.shape[0]:
            suspect[fresh_sus] = True
            touch = valid & ~overdel & np.isin(spo_all, fresh_sus).any(axis=1)
            touch[wit] = False  # already in this wave
            grabbed = np.flatnonzero(touch)
            new_rows = np.concatenate([new_rows, grabbed])

        overdel[new_rows] = True
        frontier = np.unique(new_rows)

    return np.flatnonzero(overdel), np.flatnonzero(suspect)


def delete_facts(
    state: IncrementalState, delta: np.ndarray, max_rounds: int = 10_000
) -> IncrementalState:
    """Retract explicit triples and maintain the materialisation in place.

    Rows of ``delta`` that are not currently explicit are ignored.  See the
    module docstring for the B/F algorithm; the result is oracle-equal to a
    from-scratch REW run on ``explicit \\ delta`` (tests/test_incremental.py).
    """
    t0 = time.perf_counter()
    delta = dedup_rows(delta)
    if delta.shape[0] and state.explicit.shape[0]:
        delta = delta[np.isin(pack(delta), pack(state.explicit))]
    else:
        delta = np.zeros((0, 3), np.int32)
    if delta.shape[0] == 0:
        state.stats.wall_seconds += time.perf_counter() - t0
        return state

    explicit_new = setdiff_rows(state.explicit, delta)

    # -- backward: overdelete + find suspect cliques -------------------------
    overdel_rows, suspect_reps = _overdelete(state, delta)
    state.arena.mark_rows(overdel_rows)

    # -- split: only affected connected components are recomputed ------------
    rep_split = split_cliques(state.rep, suspect_reps)

    # -- rebuild rules under the split rho (suspect constants revert) --------
    p_split, _changed = state.base_program.rewrite(rep_split)

    # -- forward: rederive and run the shared round loop ---------------------
    # seed 1: explicit facts whose normal form went missing
    miss = np.zeros(0, dtype=bool)
    seeds = []
    if explicit_new.shape[0]:
        nf = normal_forms(explicit_new, rep_split, state.use_kernel)
        miss = ~state.arena.contains(nf)
        if miss.any():
            seeds.append(explicit_new[miss])
    # seed 2: one-step rederivations — heads derivable from the surviving
    # store (old+old substitutions the delta discipline would never revisit)
    t_surv = state.arena.valid_triples()
    if t_surv.shape[0] and overdel_rows.shape[0]:
        o_spo = state.arena.spo[overdel_rows]
        for rule in p_split:
            if not _rule_may_rederive(rule, o_spo, state.rep):
                continue
            h, _nd, _na = eval_rule_full(rule, t_surv)
            if h.shape[0]:
                seeds.append(h)
        # seed 3: reflexive witnesses whose genesis survived — resources
        # still occurring in surviving facts keep their <c, sameAs, c>
        res = np.unique(np.append(np.unique(t_surv), SAME_AS))
        refl = np.stack(
            [res, np.full_like(res, SAME_AS), res], axis=1
        ).astype(np.int32)
        miss_refl = refl[~state.arena.contains(refl)]
        if miss_refl.shape[0]:
            seeds.append(miss_refl)
    cands = (
        dedup_rows(np.concatenate(seeds, axis=0))
        if seeds
        else np.zeros((0, 3), np.int32)
    )
    if cands.shape[0]:
        cands = cands[
            ~state.arena.contains(normal_forms(cands, rep_split, state.use_kernel))
        ]

    rep_new, p_new = rew_rounds(
        state.arena, rep_split, p_split, cands, state.stats, max_rounds
    )

    state.rep = rep_new
    state.program = p_new
    state.explicit = explicit_new
    state.stats.triples_explicit = explicit_new.shape[0]
    state.stats.wall_seconds += time.perf_counter() - t0
    return state
