"""Sharded incremental maintenance: SPMD overdelete/rederive on the engine.

The host subsystem (:mod:`repro.core.incremental`) runs every maintenance
round on the host, so update streams do not scale with the mesh the way the
base fixpoint in :meth:`repro.core.engine_jax.JaxEngine.materialise` does.
This module ports the add/delete rounds into the fixed-capacity SPMD engine:

**Additions** reuse the engine's forward round loop directly — the delta
batch is padded into the candidate stream and processed exactly like the
explicit facts of the base run, at the next epoch.  The epoch discipline of
``_epoch_ok`` makes the loop restartable: the first new round's delta plans
match exactly the freshly inserted rows, and old-only substitutions were
exhausted earlier.

**Deletions** are the DRed-style backward/forward pass of the host module,
with the backward closure run on-device as *epoch-tagged tombstones*:

1. *Seed*: the rho-normal forms of the deleted explicit triples are routed
   to every shard (replicated query batch); each shard tags its matching
   rows ``tomb = 0``.
2. *Overdelete waves*: wave ``w`` evaluates every rule's tombstone plans
   (:func:`repro.core.engine_jax.build_plans` with ``tombstone=True``) —
   Delta = rows with ``tomb == w-1``, all other atoms the full pre-deletion
   store — then :func:`_od_step` tags the derived heads, the reflexivity
   children of the wave's frontier, and every fact touching a freshly
   *suspect* clique (one whose reflexive witness ``<r, sameAs, r>`` was
   tombstoned).  Cross-shard delta triples are exchanged with the same
   owner-routed ``all_to_all`` (keyed on the subject representative) the
   forward rounds use; the suspect set leaves the device only as a psum'd
   boolean mask — clique split/re-merge stays a host decision.
3. *Finalize*: tombstones flip to ``marked`` (the paper's mark-don't-delete
   bit), per-position masks of the overdeleted normal forms are reduced for
   the host-side rederive rule filter, and ``tomb`` resets to -1 — the
   invariant the forward predicates rely on.
4. *Split + rederive*: the host splits suspect cliques
   (:func:`repro.core.uf.split_cliques` — only rho bookkeeping leaves the
   device), re-rewrites the base program under the split rho, and runs
   **targeted rederivation**: for each rule whose head pattern can restore
   an overdeleted fact, the head variables are pre-bound to the overdeleted
   instances (:func:`_head_bindings` on the finalised tombstone set) and
   the body is chained backward through the persistent sorted index
   (:func:`repro.core.engine_jax.eval_plan_rederive`) — the B/F refinement
   of DRed's rederive step, with join cost proportional to the overdelete
   delta rather than the surviving store.  The restored instances seed the
   shared forward loop together with (a) still-explicit triples whose
   normal form went missing and (b) missing reflexive witnesses of
   surviving resources; only variable-free heads still fall back to a
   whole-rule requeue.  Re-merging then happens through the normal round
   machinery (``merge_pairs_jax`` + the Algorithm-3 sweep).

Correctness oracle (tests/test_incremental_spmd.py + the differential fuzz
harness in tests/test_incremental.py): after any update sequence the state
equals the from-scratch REW materialisation of the updated explicit set —
same rho, same normal-form store — and is invariant to the device count.
"""

from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .engine_jax import (
    I32,
    KEY_MAX,
    CapacityError,
    EngineState,
    _compact as _engine_compact,
    _index_remove,
    _pack3,
    _pow2,
    _route_rows,
    register_auditable,
)
from repro.kernels import ops as kernel_ops

from .terms import SAME_AS, is_var
from .triples import dedup_rows, pack, setdiff_rows
from .uf import clique_sizes, split_cliques

__all__ = [
    "spmd_add_facts",
    "spmd_add_phases",
    "spmd_delete_facts",
    "spmd_delete_phases",
    "static_dispatch_profile",
]


# ---------------------------------------------------------------------------
# per-shard step functions (pure; run under shard_map via engine._wrap)
# ---------------------------------------------------------------------------

def _probe_index(sorted_keys, sort_perm, select, queries, qvalid):
    """Row index of each query triple among the locally ``select``-ed rows,
    via the shard's persistent sorted index — no arena sort per probe.

    Returns ``(rows, hit)`` — rows are clamped-garbage where ``hit`` is
    False.  Live keys are unique by the arena's insert-time dedup, so at
    most one index entry matches a query; ``select`` prunes subsets of the
    live rows (e.g. already-tombstoned ones).  Invalid query slots are
    excluded by masking ``hit`` with ``qvalid`` explicitly — the former
    ``KEY_MAX - 1`` sentinel aliased a legitimate packed key at the 21-bit
    ID boundary.
    """
    qk = _pack3(queries)
    pos = jnp.clip(jnp.searchsorted(sorted_keys, qk), 0, sorted_keys.shape[0] - 1)
    rows = sort_perm[pos]
    hit = (sorted_keys[pos] == qk) & qvalid & select[rows]
    return rows, hit


def _psum_bool(x, axis):
    if axis is None:
        return x
    return jax.lax.psum(x.astype(I32), axis) > 0


def _seed_tombs(sorted_keys, sort_perm, epoch, marked, tomb, q, qv, *, axis):
    """Tag wave-0 tombstones: local rows matching the replicated queries."""
    untagged = (epoch >= 0) & ~marked & (tomb < 0)
    rows, hit = _probe_index(sorted_keys, sort_perm, untagged, q, qv)
    tgt = jnp.where(hit, rows, tomb.shape[0])
    tomb = tomb.at[tgt].set(jnp.zeros(tgt.shape, I32), mode="drop")
    n = hit.sum().astype(I32)
    if axis is not None:
        n = jax.lax.psum(n, axis)
    return tomb, n[None]


def _od_step(
    spo, epoch, marked, tomb, sorted_keys, sort_perm, rep, sizes, suspect,
    heads, hv, w,
    *, axis, n_shards, route_cap, refl_cap,
    with_masks: bool = True, use_kernel: bool = False,
):
    """One overdelete wave: tag heads + reflexivity children, detect suspect
    cliques (psum'd mask — the only state that leaves the shard), and grab
    every live fact touching a fresh suspect.  Returns
    ``(tomb', suspect', n_new, overflow, frontier_masks)``.

    ``with_masks=False`` skips the per-position frontier mask reduction
    (returning all-False masks): the fused wave loop evaluates every
    tombstone plan unconditionally, so the host-side plan filter the masks
    feed never runs — dead-plan skipping is an orchestration optimisation,
    not a semantic one (a skipped plan's delta atom matches zero rows).
    """
    C = spo.shape[0]
    store = (epoch >= 0) & ~marked  # the pre-deletion store (DRed's T)
    frontier = store & (tomb == w - 1)

    # heads derived from the wave's delta plans, normalised under rho
    heads_n = jnp.where(hv[:, None], rep[heads], 0).astype(I32)

    # reflexivity children: <c, sameAs, c> for every resource of the
    # frontier (plus the sameAs row itself, mirroring the host pass).  The
    # frontier is compacted first so the stream scales with the wave, not
    # the arena; overflow raises the update's capacity retry.
    fcols, fvalid, f_ov = _engine_compact(
        {"s": spo[:, 0], "p": spo[:, 1], "o": spo[:, 2]}, frontier, refl_cap
    )
    f_spo = jnp.stack([fcols["s"], fcols["p"], fcols["o"]], axis=1)
    res = f_spo.reshape(-1)
    res_v = jnp.repeat(fvalid, 3)
    refl = jnp.stack([res, jnp.full_like(res, SAME_AS), res], axis=1)
    sa_row = jnp.asarray([[SAME_AS] * 3], I32)
    any_f = frontier.any()
    stream = jnp.concatenate([heads_n, refl, sa_row], axis=0)
    sv = jnp.concatenate([hv, res_v, any_f[None]])

    # dedup locally before the exchange (shrinks bucket pressure)
    keys = jnp.where(sv, _pack3(stream), KEY_MAX)
    if use_kernel:  # sort-free Pallas counting-rank dedup
        order = kernel_ops.dedup_order(keys)
    else:
        order = jnp.argsort(keys)
    sk = keys[order]
    uniq = jnp.concatenate([jnp.asarray([True]), sk[1:] != sk[:-1]])
    stream, sv = stream[order], uniq & (sk < KEY_MAX)

    # owner-routed delta exchange, keyed on the subject representative
    stream, _, sv, overflow = _route_rows(
        stream, None, sv, axis, n_shards, route_cap
    )

    # tombstone the matching local rows that are not already tagged —
    # probed against the persistent index (tomb tagging does not change
    # liveness, so the index stays exact across the whole backward pass)
    untagged = store & (tomb < 0)
    rows, hit = _probe_index(sorted_keys, sort_perm, untagged, stream, sv)
    tgt = jnp.where(hit, rows, C)
    tomb = tomb.at[tgt].set(jnp.where(hit, w, 0).astype(I32), mode="drop")

    # suspect cliques: a tombstoned reflexive witness <r, sameAs, r> of a
    # multi-member clique means every merge of that clique lost its proof.
    # Checked on this wave's new rows AND the frontier so the wave-0 seeds
    # are examined exactly once (grabbed rows are re-checked next wave).
    wit = store & ((tomb == w) | (tomb == w - 1))
    is_wit = (
        wit
        & (spo[:, 1] == SAME_AS)
        & (spo[:, 0] == spo[:, 2])
        & (sizes[spo[:, 0]] > 1)
    )
    cand = jnp.zeros(rep.shape[0], bool).at[
        jnp.where(is_wit, spo[:, 0], 0)
    ].max(is_wit)
    cand = _psum_bool(cand, axis)
    fresh = cand & ~suspect
    suspect = suspect | cand

    # grab: a stored normal form conflates members of a split clique, so
    # every live fact touching a fresh suspect must be rederived
    touch = fresh[spo[:, 0]] | fresh[spo[:, 1]] | fresh[spo[:, 2]]
    grab = store & (tomb < 0) & touch
    tomb = jnp.where(grab, w, tomb)

    new = store & (tomb == w)
    n_new = new.sum().astype(I32)
    if axis is not None:
        n_new = jax.lax.psum(n_new, axis)

    # per-position resource masks of the wave's new rows: the host driver
    # skips next wave's tombstone plans whose delta atom cannot match them
    if with_masks:
        fm = []
        for pos in range(3):
            fm.append(
                jnp.zeros(rep.shape[0], bool).at[
                    jnp.where(new, spo[:, pos], 0)
                ].max(new)
            )
        od_masks = _psum_bool(jnp.stack(fm), axis)
    else:
        od_masks = jnp.zeros((3, rep.shape[0]), bool)
    return tomb, suspect, n_new[None], overflow[None], f_ov[None], od_masks


def _finalize_tombs(spo, epoch, marked, tomb, sorted_keys, sort_perm, rep, *, axis):
    """Flip tombstones into the paper's outdated bit and reduce the
    per-position masks of overdeleted normal forms (the host-side rederive
    rule filter).  Restores the ``tomb == -1`` forward invariant; the
    finalised rows leave the persistent index by a stable partition (no
    sort), keeping it exact for the rederive phase's membership probes."""
    tombed = tomb >= 0
    masks = []
    for pos in range(3):
        m = jnp.zeros(rep.shape[0], bool).at[
            jnp.where(tombed, spo[:, pos], 0)
        ].max(tombed)
        masks.append(m)
    od_mask = jnp.stack(masks)  # (3, n_res)
    od_mask = _psum_bool(od_mask, axis)
    n_od = tombed.sum().astype(I32)
    if axis is not None:
        n_od = jax.lax.psum(n_od, axis)
    marked = marked | tombed
    tomb = jnp.full_like(tomb, -1)
    sort_perm, sorted_keys = _index_remove(
        sort_perm, sorted_keys, tombed, spo.shape[0] - 1
    )
    return marked, tomb, sorted_keys, sort_perm, od_mask, n_od[None]


def _extract_tombed(spo, tomb, *, axis, cap):
    """Compact the overdeleted rows (``tomb >= 0``) — the finalised
    tombstone set that drives targeted rederivation.  Must run BEFORE
    :func:`_finalize_tombs` resets ``tomb``; ``cap`` is sized from the
    host's running overdelete count (a global bound, hence per-shard
    sufficient), so the overflow flag only fires if the driver miscounted.
    """
    del axis  # per-shard compaction; the host concatenates the blocks
    tombed = tomb >= 0
    cols, valid, ov = _engine_compact(
        {"s": spo[:, 0], "p": spo[:, 1], "o": spo[:, 2]}, tombed, cap
    )
    rows = jnp.stack([cols["s"], cols["p"], cols["o"]], axis=1)
    return rows, valid, ov[None]


def _member(sorted_keys, q, qv, *, axis):
    """Replicated membership of query triples among live store rows.

    The index contains exactly the live rows, so a key hit IS liveness —
    no row lookup or epoch/marked recheck needed.  The all-max-ID triple
    packs to KEY_MAX itself (the padding sentinel, reserved — see
    ``terms.MAX_ID``) and must not match the padding.
    """
    qk = _pack3(q)
    pos = jnp.clip(jnp.searchsorted(sorted_keys, qk), 0, sorted_keys.shape[0] - 1)
    hit = (sorted_keys[pos] == qk) & qv & (qk < KEY_MAX)
    return _psum_bool(hit, axis)


def _occupancy(spo, epoch, marked, rep, *, axis):
    """Replicated mask of resources occurring in live store rows."""
    live = (epoch >= 0) & ~marked
    res = spo.reshape(-1)
    lv = jnp.repeat(live, 3)
    occ = jnp.zeros(rep.shape[0], bool).at[jnp.where(lv, res, 0)].max(lv)
    return _psum_bool(occ, axis)


# ---------------------------------------------------------------------------
# wrapped-fn getters (cached on the engine like its plan/process fns)
# ---------------------------------------------------------------------------

_KEY_FAMILY = {"refl_cap": "out", "route_cap": "route"}


def _get_step_fn(engine, name, fn, in_specs, out_specs, **static):
    # cap-valued statics are tagged with their buffer family so the
    # engine's precise post-growth eviction finds them
    key = (name,) + tuple(
        sorted((_KEY_FAMILY.get(k, k), v) for k, v in static.items())
    )
    if key not in engine._fns:
        a = engine.axis
        engine._register_fn(key, engine._wrap(
            partial(fn, axis=a, **static), in_specs=in_specs, out_specs=out_specs
        ))
    return engine._fns[key]


def _specs(engine):
    a = engine.axis
    d = P(a) if a else None
    rpl = P() if a else None
    return d, rpl


def _seed_fn(engine):
    d, rpl = _specs(engine)
    return _get_step_fn(
        engine, "seed_tombs", _seed_tombs,
        in_specs=(d, d, d, d, d, rpl, rpl), out_specs=(d, rpl),
    )


def _od_fn(engine, n_heads: int):
    d, rpl = _specs(engine)
    route_cap = engine.route_cap if engine.axis is not None else None
    return _get_step_fn(
        engine, ("od", n_heads), _od_step,
        in_specs=(d, d, d, d, d, d, rpl, rpl, rpl, d, d, rpl),
        out_specs=(d, rpl, rpl, d, d, rpl),
        n_shards=engine.n_shards, route_cap=route_cap,
        refl_cap=engine._active_delta_out,
        use_kernel=engine.use_kernel,
    )


def _fwave_fn(engine, plans_sig: tuple):
    """Wrapped :func:`repro.core.fused.fused_delete_waves` for this engine.

    Keyed like the engine's own fused-forward fn: the plan signature plus
    every cap the trace closes over, each tagged with its buffer family so
    post-growth eviction stays precise."""
    key = (
        "fwave", plans_sig,
        ("bind", engine._active_bind), ("out", engine._active_delta_out),
        ("route", engine.route_cap),
    )
    if key not in engine._fns:
        from .fused import fused_delete_waves

        a = engine.axis
        fn = partial(
            fused_delete_waves,
            plans=plans_sig,
            bind_cap=engine._active_bind,
            plan_out_cap=engine._active_delta_out,
            route_cap=engine.route_cap if a is not None else None,
            refl_cap=engine._active_delta_out,
            axis=a,
            n_shards=engine.n_shards,
            use_kernel=engine.use_kernel,
        )
        d, rpl = _specs(engine)
        flag_specs = {
            k: rpl
            for k in (
                "iters", "n_od", "n_new",
                "ov_route", "ov_refl", "ov_bind", "ov_out", "ov_squeeze",
            )
        }
        engine._register_fn(key, engine._wrap(
            fn,
            in_specs=(d, d, d, d, d, d, rpl, rpl, rpl, rpl, rpl, rpl),
            out_specs=(d, rpl, flag_specs),
        ))
    return engine._fns[key]


def _finalize_fn(engine):
    d, rpl = _specs(engine)
    return _get_step_fn(
        engine, "finalize_tombs", _finalize_tombs,
        in_specs=(d, d, d, d, d, d, rpl), out_specs=(d, d, d, d, rpl, rpl),
    )


def _extract_fn(engine, cap: int):
    d, rpl = _specs(engine)
    return _get_step_fn(
        engine, "extract_od", _extract_tombed,
        in_specs=(d, d), out_specs=(d, d, d), cap=cap,
    )


def _member_fn(engine):
    d, rpl = _specs(engine)
    return _get_step_fn(
        engine, "member", _member,
        in_specs=(d, rpl, rpl), out_specs=rpl,
    )


def _occ_fn(engine):
    d, rpl = _specs(engine)
    return _get_step_fn(
        engine, "occupancy", _occupancy,
        in_specs=(d, d, d, rpl), out_specs=rpl,
    )


# ---------------------------------------------------------------------------
# host-side helpers
# ---------------------------------------------------------------------------

def _chunks(rows: np.ndarray, size: int):
    for i in range(0, rows.shape[0], size):
        chunk = rows[i : i + size]
        padn = size - chunk.shape[0]
        q = np.pad(chunk, ((0, padn), (0, 0))).astype(np.int32)
        qv = np.arange(size) < chunk.shape[0]
        yield chunk.shape[0], jnp.asarray(q), jnp.asarray(qv)


def _seed_query(engine, state: EngineState, rows: np.ndarray) -> int:
    """Tag wave-0 tombstones for ``rows`` (chunked replicated queries)."""
    total = 0
    fn = _seed_fn(engine)
    for _n, q, qv in _chunks(rows, engine.seed_chunk):
        state.tomb, n = fn(
            state.sorted_keys, state.sort_perm, state.epoch, state.marked,
            state.tomb, q, qv,
        )
        total += int(np.asarray(n).reshape(-1)[0])
    return total


def _member_query(engine, state: EngineState, rows: np.ndarray) -> np.ndarray:
    """Boolean membership of ``rows`` among live store rows (chunked)."""
    if rows.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    fn = _member_fn(engine)
    out = []
    for n, q, qv in _chunks(rows, engine.seed_chunk):
        hit = np.asarray(fn(state.sorted_keys, q, qv))
        out.append(hit[:n])
    return np.concatenate(out)


def _tomb_heads(engine, state: EngineState, w: int, masks: np.ndarray):
    """Evaluate the tombstone delta plans for wave ``w``, skipping plans
    whose delta atom cannot match the frontier (``masks`` = the previous
    wave's per-position resource masks).  Wide bucketed head streams are
    squeezed to the active delta width so the wave step's dedup/probe work
    scales with the wave, not with the number of rules that fired."""
    bufs = []
    for k, rule in enumerate(state.program.rules):
        bufs += engine._eval_rule(state, w, rule, k, "tomb", None, delta_masks=masks)
    if not bufs:
        return jnp.zeros((0, 3), I32), jnp.zeros((0,), bool)
    heads, hv = engine._bucket_cands(bufs)
    rows_global = engine._active_delta_out * engine.n_shards
    if int(heads.shape[0]) > rows_global:
        sq = engine._get_squeeze_fn(int(heads.shape[0]), engine._active_delta_out)
        heads, hv, sq_ov = sq(heads, hv)
        if bool(np.asarray(sq_ov).any()):
            raise CapacityError(engine._active_delta_kind)
    return heads, hv


def _head_may_rederive(rule, od_mask: np.ndarray, rep_old: np.ndarray) -> bool:
    """False iff no overdeleted fact can match the rule's head pattern.

    Per-position relaxation of the host filter (a superset, hence sound):
    head constants are collapsed through the *pre-deletion* rho because the
    overdelete masks were reduced over pre-split normal forms while the rule
    was rewritten under the post-split rho.
    """
    for pos, t in enumerate(rule.head):
        if not is_var(t) and not od_mask[pos][rep_old[t]]:
            return False
    return True


def _head_bindings(rule, od_rows: np.ndarray, rep_old: np.ndarray):
    """Head-variable bindings of the overdeleted instances matching
    ``rule``'s head pattern, or ``None`` for a variable-free head.

    The exact (row-wise) version of :func:`_head_may_rederive`'s
    per-position relaxation, sharing its pre-/post-split correspondence:
    ``od_rows`` are normal forms under the PRE-deletion rho while the rule
    is rewritten under the post-split rho, so head constants are collapsed
    through ``rep_old`` before comparing (a split only refines cliques, so
    ``rep_old[rho_split(c)] == rep_old[c]``).  Variable positions need no
    mapping: a restorable instance binds its head variables from surviving
    store rows, whose values are pre-deletion representatives already —
    bindings holding a *split* representative simply match nothing live
    (those facts come back through the explicit re-insertion seeds).

    Rows are deduplicated; column order is the head's first-occurrence
    variable order — the seed-table contract of
    :func:`repro.core.engine_jax.build_rederive_plan`.
    """
    m = np.ones(od_rows.shape[0], dtype=bool)
    first: dict[int, int] = {}
    for pos, t in enumerate(rule.head):
        if is_var(t):
            if t in first:
                m &= od_rows[:, pos] == od_rows[:, first[t]]
            else:
                first[t] = pos
        else:
            m &= od_rows[:, pos] == rep_old[t]
    if not first:
        return None
    cols = [od_rows[m, pos] for pos in first.values()]
    return np.unique(np.stack(cols, axis=1), axis=0).astype(np.int32)


# ---------------------------------------------------------------------------
# drivers (called by JaxEngine.add_facts / delete_facts inside enable_x64)
# ---------------------------------------------------------------------------

def spmd_add_phases(engine, state: EngineState, delta, max_rounds: int):
    """Phase generator behind :func:`spmd_add_facts`.

    Yields a label at each point a serving scheduler may interleave other
    work (the mutation is NOT epoch-consistent until the generator is
    exhausted): ``"prepared"`` after the explicit-set bookkeeping, then the
    forward fixpoint runs to completion.  A driver must either exhaust the
    generator or roll the state back to a snapshot taken before it started
    (:meth:`JaxEngine._snapshot`) — e.g. on :class:`CapacityError`, whose
    retry restarts the phases from scratch against the restored state.
    A no-effect delta yields nothing.
    """
    tag = engine.dispatches
    try:
        tag.phase = "add:prepare"
        engine._ensure_index(state)  # rebuild only after a capacity re-layout
        delta = dedup_rows(delta)
        delta = setdiff_rows(delta, state.explicit)
        if delta.shape[0] == 0:
            return
        hi = int(delta.max()) + 1
        if hi > state.n_res:  # unseen resource IDs: extend rho with identities
            rep_host = np.asarray(state.rep)
            ext = np.arange(rep_host.shape[0], hi, dtype=rep_host.dtype)
            state.rep = jnp.asarray(np.concatenate([rep_host, ext]))
        state.explicit = np.concatenate([state.explicit, delta], axis=0)
        state.stats.triples_explicit = state.explicit.shape[0]
        engine._presize_delta(delta.shape[0])  # known admitted-batch cardinality
        cands, cand_valid = engine._pad_cands(delta)
        yield "prepared"
        tag.phase = "add:forward"
        engine._forward(state, cands, cand_valid, [], max_rounds)
    finally:
        tag.phase = None


def spmd_add_facts(engine, state: EngineState, delta, max_rounds: int) -> EngineState:
    """Additions: seed the engine's forward loop with the fresh triples."""
    for _phase in spmd_add_phases(engine, state, delta, max_rounds):
        pass
    return state


def spmd_delete_phases(engine, state: EngineState, delta, max_rounds: int):
    """Phase generator behind :func:`spmd_delete_facts`.

    Yield points mark the scheduler-visible stages of the DRed pass:

      * ``"seeded"`` — wave-0 tombstones tagged for the deleted normal forms,
      * ``"wave"`` — after each overdelete wave that tagged new tombstones,
      * ``"overdeleted"`` — tombstones finalised into ``marked`` (the live
        arena now HIDES overdeleted rows that rederivation will restore —
        the mid-round state an epoch snapshot must never expose),
      * ``"split"`` — suspect cliques reverted to singletons and the program
        re-rewritten under the split rho,
      * ``"rederive"`` — the targeted (head-bound, backward-chained)
        rederivation joins have produced their restored instances; the
        forward fixpoint then runs to completion and the generator ends.

    Same contract as :func:`spmd_add_phases`: exhaust or roll back; a
    no-effect delta yields nothing.
    """
    tag = engine.dispatches
    try:
        yield from _delete_phases_tagged(engine, state, delta, max_rounds, tag)
    finally:
        tag.phase = None


def _delete_phases_tagged(engine, state, delta, max_rounds, tag):
    tag.phase = "delete:prepare"
    engine._ensure_index(state)  # rebuild only after a capacity re-layout
    delta = dedup_rows(delta)
    if delta.shape[0] and state.explicit.shape[0]:
        delta = delta[np.isin(pack(delta), pack(state.explicit))]
    else:
        delta = np.zeros((0, 3), np.int32)
    if delta.shape[0] == 0:
        return

    explicit_new = setdiff_rows(state.explicit, delta)
    rep_host = np.asarray(state.rep)
    sizes = clique_sizes(rep_host)

    # -- backward: seed + overdelete waves (epoch-tagged tombstones) ---------
    if engine.use_kernel:
        from repro.kernels.rewrite_triples import rewrite_owner

        nf_j, owner_j = rewrite_owner(
            jnp.asarray(delta, jnp.int32),
            jnp.asarray(rep_host, jnp.int32),
            engine.n_shards,
        )
        nf, owner = np.asarray(nf_j), np.asarray(owner_j)
    else:
        nf = rep_host[delta].astype(np.int32)
        owner = nf[:, 0] % engine.n_shards
    # owner-sorted queries: each shard's matches land in contiguous runs
    nf = dedup_rows(nf[np.argsort(owner, kind="stable")])
    tag.phase = "delete:seed"
    n_od_host = _seed_query(engine, state, nf)
    yield "seeded"
    tag.phase = "delete:wave"

    # wave-1 frontier masks come from the seed normal forms themselves
    masks = np.zeros((3, state.n_res), dtype=bool)
    for pos in range(3):
        masks[pos][nf[:, pos]] = True

    suspect = jnp.zeros((state.n_res,), bool)
    sizes_j = jnp.asarray(sizes, dtype=I32)
    if engine.fuse_rounds:
        # one compiled fixpoint over every wave: tombstone plans + od step
        # run in a single lax.while_loop, convergence decided on device.
        # The host's dead-plan mask filtering is dropped (impossible plans
        # match zero rows inside the trace) — what it saved in compute it
        # cost in per-wave dispatches, the quantity this path exists to kill.
        from .fused import forward_plan_signature, program_tables

        plans_sig = forward_plan_signature(state.program, tombstone=True)
        fn = _fwave_fn(engine, plans_sig)
        ac, hc, _cv, _cvd = program_tables(state.program)
        state.tomb, suspect, fl = fn(
            state.spo, state.epoch, state.marked, state.tomb,
            state.sorted_keys, state.sort_perm, state.rep, sizes_j, suspect,
            jnp.asarray(max_rounds, I32), ac, hc,
        )

        def _flag(name: str) -> bool:
            return bool(np.asarray(fl[name]).reshape(-1)[0])

        state.stats.od_waves += int(np.asarray(fl["iters"]).reshape(-1)[0])
        if _flag("ov_route"):
            raise CapacityError("route")
        if _flag("ov_bind"):
            raise CapacityError(engine._active_bind_kind)
        if _flag("ov_refl") or _flag("ov_out") or _flag("ov_squeeze"):
            # the reflexivity buffer and the plan-output stream are both
            # sized by the ACTIVE delta width — under the wide-buffer
            # fallback that is out_cap, whose growth kind must be named or
            # the (clamped) delta cap would stop growing and the retry loop
            # would spin on the same overflow
            raise CapacityError(engine._active_delta_kind)
        if int(np.asarray(fl["n_new"]).reshape(-1)[0]) > 0:
            raise RuntimeError("did not converge")
        n_wave_total = int(np.asarray(fl["n_od"]).reshape(-1)[0])
        n_od_host += n_wave_total
        if n_wave_total:
            yield "wave"
    else:
        w = 0
        while True:
            w += 1
            state.stats.od_waves += 1
            heads, hv = _tomb_heads(engine, state, w, masks)
            fn = _od_fn(engine, int(heads.shape[0]))
            state.tomb, suspect, n_new, ov_route, ov_refl, od_masks = fn(
                state.spo, state.epoch, state.marked, state.tomb,
                state.sorted_keys, state.sort_perm,
                state.rep, sizes_j, suspect, heads, hv, jnp.asarray(w, I32),
            )
            if bool(np.asarray(ov_route).any()):
                raise CapacityError("route")
            if bool(np.asarray(ov_refl).any()):
                # the reflexivity buffer is sized by the ACTIVE delta width —
                # under the wide-buffer fallback that is out_cap, whose
                # growth kind must be named or the (clamped) delta cap would
                # stop growing and the retry loop would spin on the same
                # overflow
                raise CapacityError(engine._active_delta_kind)
            n_wave = int(np.asarray(n_new).reshape(-1)[0])
            if n_wave == 0:
                break
            n_od_host += n_wave
            masks = np.asarray(od_masks)
            yield "wave"

    tag.phase = "delete:finalize"
    # pre-size the delta buffers from the now-known overdelete cardinality:
    # the rederive seeds and the restored candidate stream scale with it,
    # and discovering that width by overflow restarts mid-stream is the
    # direct mechanism behind the uobm_like steady-event regression
    engine._presize_delta(max(n_od_host, delta.shape[0]))

    # grab the overdeleted rows for the head-bound rederive joins while the
    # tombstone column still identifies them (finalize resets it to -1)
    od_rows = np.zeros((0, 3), np.int32)
    if n_od_host and engine.rederive_mode == "targeted":
        rows, rv, ov = _extract_fn(engine, _pow2(n_od_host))(
            state.spo, state.tomb
        )
        if bool(np.asarray(ov).any()):
            # the extract buffer is sized from the host's running count, so
            # overflow means the count itself is wrong — an invariant
            # violation no capacity growth can fix; surfacing it as a
            # CapacityError would spin the retry loop growing unrelated
            # caps against the same miscount forever
            raise RuntimeError(
                "overdelete extraction overflowed its host-counted bound "
                f"({n_od_host} rows) — tombstone accounting is inconsistent"
            )
        od_rows = np.asarray(rows).reshape(-1, 3)[np.asarray(rv).reshape(-1)]

    (
        state.marked, state.tomb, state.sorted_keys, state.sort_perm,
        od_mask, n_od,
    ) = _finalize_fn(engine)(
        state.spo, state.epoch, state.marked, state.tomb,
        state.sorted_keys, state.sort_perm, state.rep,
    )
    n_od = int(np.asarray(n_od).reshape(-1)[0])
    state.stats.overdeleted += n_od
    yield "overdeleted"

    # -- split: suspect cliques revert to singletons (host rho bookkeeping) --
    suspect_reps = np.flatnonzero(np.asarray(suspect))
    state.stats.suspects_split += int(suspect_reps.shape[0])
    rep_split = split_cliques(rep_host, suspect_reps)
    p_split, _ = state.base_program.rewrite(rep_split)
    state.rep = jnp.asarray(rep_split.astype(np.int32))
    state.program = p_split
    yield "split"
    tag.phase = "delete:rederive"

    # -- rederive: restore overdeleted facts still derivable from survivors --
    # Targeted (default): for each rule whose head pattern can match an
    # overdeleted instance, bind the head variables to those instances and
    # chain the body backward through the persistent sorted index — the
    # DRed/B-F one-step rederivation, with cost proportional to the
    # overdelete delta.  The restored instances seed the forward fixpoint,
    # whose delta discipline finds every consequence.  Whole-rule requeue
    # (evaluating the rule unconstrained against the surviving store)
    # remains only for variable-free heads — a head with no variables
    # admits no instance constraint — and as the "requeue" differential
    # baseline.
    od_mask_h = np.asarray(od_mask)
    requeued = []
    rederived: list[np.ndarray] = []
    if n_od:
        for k, rule in enumerate(p_split.rules):
            if not _head_may_rederive(rule, od_mask_h, rep_host):
                continue
            if engine.rederive_mode != "targeted":
                requeued.append(k)
                state.stats.rederive_full_fallback += 1
                continue
            bind = _head_bindings(rule, od_rows, rep_host)
            if bind is None:
                requeued.append(k)
                state.stats.rederive_full_fallback += 1
            elif bind.shape[0]:
                heads = engine._eval_rule_rederive(state, k, rule, bind)
                state.stats.rederive_targeted += 1
                if heads.shape[0]:
                    rederived.append(heads)
    yield "rederive"

    # seeds: the rederived instances, explicit rows whose (post-split)
    # normal form went missing, and missing reflexive witnesses of
    # resources surviving in the store
    seeds = rederived
    if explicit_new.shape[0]:
        nf_exp = rep_split[explicit_new].astype(np.int32)
        miss = ~_member_query(engine, state, nf_exp)
        if miss.any():
            seeds.append(explicit_new[miss])
    occ = np.asarray(_occ_fn(engine)(state.spo, state.epoch, state.marked, state.rep))
    if occ.any() and n_od:
        res = np.union1d(np.flatnonzero(occ), [SAME_AS]).astype(np.int32)
        refl = np.stack([res, np.full_like(res, SAME_AS), res], axis=1)
        miss_refl = refl[~_member_query(engine, state, refl)]
        if miss_refl.shape[0]:
            seeds.append(miss_refl)
    cands = (
        dedup_rows(np.concatenate(seeds, axis=0))
        if seeds
        else np.zeros((0, 3), np.int32)
    )

    state.explicit = explicit_new
    state.stats.triples_explicit = explicit_new.shape[0]
    cj, cv = engine._pad_cands(cands)
    tag.phase = "delete:forward"
    engine._forward(state, cj, cv, requeued, max_rounds)


def spmd_delete_facts(engine, state: EngineState, delta, max_rounds: int) -> EngineState:
    """Deletions: tombstone waves on-device, split on host, rederive on-device."""
    for _phase in spmd_delete_phases(engine, state, delta, max_rounds):
        pass
    return state


# ---------------------------------------------------------------------------
# dispatch auditor (static half) + audit trace builders (repro.analysis)
# ---------------------------------------------------------------------------

def static_dispatch_profile(program=None) -> dict:
    """Which compiled-fn families each maintenance phase may dispatch.

    The static half of the DispatchAuditor.  Keys are the phase labels the
    generators tag on ``engine.dispatches``; values map each admissible fn
    family to its static dispatch count per unit of that phase — per
    forward ROUND, per overdelete WAVE, per query CHUNK, or per OPERATION —
    the dispatch floor the ROADMAP's fused-fixpoint item is trying to
    lower.  With ``program`` the plan counts are exact for that rule set
    (one delta/tomb plan per body atom; mask filtering and full-plan
    requeues make the observed count vary around them); without it they are
    ``None`` (family admissible, count unstated).  The runtime counter
    (:class:`repro.core.stats.DispatchCounter`) is reconciled against this
    table by :func:`repro.analysis.dispatch_crosscheck` — a family
    dispatching inside a phase that does not list it means a compiled fn
    joined a hot path without declaring itself to the auditor.
    """
    n_plans = (
        sum(len(r.body) for r in program.rules) if program is not None else None
    )
    n_rules = len(program.rules) if program is not None else None
    # the shared forward round.  Fused engines (fuse_rounds=True, the
    # default) dispatch ONE ``fforward`` fixpoint per convergence stretch;
    # host-loop engines (and the wide/requeued rounds the fused branch
    # hands back to the host body) dispatch one process step, the delta
    # plans, and at most one squeeze PER ROUND.  Rounds whose rho merge
    # rewrote rule constants additionally dispatch one merge-targeted
    # ``mplan`` per changed rule (the forward-side analogue of ``rplan``;
    # the "plan" full-mode requeue remains only as the ground-anchor
    # fallback and the rederive_mode="requeue" baseline).
    forward = {
        "fforward": 1, "process": 1, "plan": n_plans, "squeeze": 1,
        "mplan": n_rules,
    }
    return {
        "add:prepare": {"rebuild_index": 1},          # only if index dirty
        "add:forward": dict(forward),
        "delete:prepare": {"rebuild_index": 1},       # only if index dirty
        "delete:seed": {"seed_tombs": 1},             # per query chunk
        # fused: one ``fwave`` fixpoint for ALL waves; host loop: the
        # tombstone plans + squeeze + od step per wave
        "delete:wave": {
            "fwave": 1, "plan": n_plans, "squeeze": 1, "od": 1,
        },
        "delete:finalize": {"extract_od": 1, "finalize_tombs": 1},
        # per matching rule, plus the seed membership/occupancy probes that
        # assemble the forward seeds (member: per query chunk)
        "delete:rederive": {"rplan": n_rules, "member": 1, "occupancy": 1},
        "delete:forward": dict(forward),
        # the capacity-retry machinery (rollback, growth, arena re-layout)
        # tags its own dispatches "retry" so restart costs never masquerade
        # as phase work; the restarted generator re-tags from the top, so
        # only the recovery step itself (at most an index rebuild after a
        # re-layout) may dispatch here
        "retry": {"rebuild_index": 1},
        # serving-tier phases (repro.serve / repro.sparql.batched).
        # "publish" is the per-barrier snapshot publication: one snapshot
        # build, plus an index rebuild riding along when the arena was
        # re-laid-out this epoch.  "query" is batched BGP execution: one
        # ``bgp`` dispatch per (shape, batch) group drained — the count per
        # drain varies with the query mix, so it is admissible-unstated.
        "publish": {"snapshot": 1, "rebuild_index": 1},
        "query": {"bgp": None},
    }


# Builders trace the per-shard step fns exactly as dispatched (single
# device, un-jitted) at the caller's probe geometry.  The ``od`` /
# ``finalize_tombs`` / ``occupancy`` exemptions are deliberate: their
# per-``n_res`` mask reductions scatter arena-length update streams by
# design (the accepted DRed bookkeeping cost), and the arena-length probes
# stay gather-based.

def _audit_chunk(engine):
    q = jnp.zeros((engine.seed_chunk, 3), I32)
    qv = jnp.zeros((engine.seed_chunk,), bool)
    return q, qv


@register_auditable("seed_tombs")
def _audit_seed_tombs(engine, state):
    q, qv = _audit_chunk(engine)
    fn = partial(_seed_tombs, axis=None)
    jx = jax.make_jaxpr(fn)(
        state.sorted_keys, state.sort_perm, state.epoch, state.marked,
        state.tomb, q, qv,
    )
    yield "seed_tombs", jx


@register_auditable("od", skip_passes=("NoArenaScatter",))
def _audit_od(engine, state):
    n_heads = engine.delta_out
    fn = partial(
        _od_step, axis=None, n_shards=1, route_cap=None,
        refl_cap=engine.delta_out,
    )
    jx = jax.make_jaxpr(fn)(
        state.spo, state.epoch, state.marked, state.tomb,
        state.sorted_keys, state.sort_perm, state.rep,
        jnp.zeros((state.n_res,), I32), jnp.zeros((state.n_res,), bool),
        jnp.zeros((n_heads, 3), I32), jnp.zeros((n_heads,), bool),
        jnp.asarray(1, I32),
    )
    yield "od", jx


@register_auditable("finalize_tombs", skip_passes=("NoArenaScatter",))
def _audit_finalize_tombs(engine, state):
    fn = partial(_finalize_tombs, axis=None)
    jx = jax.make_jaxpr(fn)(
        state.spo, state.epoch, state.marked, state.tomb,
        state.sorted_keys, state.sort_perm, state.rep,
    )
    yield "finalize_tombs", jx


@register_auditable("extract_od")
def _audit_extract_od(engine, state):
    fn = partial(_extract_tombed, axis=None, cap=64)
    jx = jax.make_jaxpr(fn)(state.spo, state.tomb)
    yield "extract_od", jx


@register_auditable("member")
def _audit_member(engine, state):
    q, qv = _audit_chunk(engine)
    fn = partial(_member, axis=None)
    jx = jax.make_jaxpr(fn)(state.sorted_keys, q, qv)
    yield "member", jx


@register_auditable("occupancy", skip_passes=("NoArenaScatter",))
def _audit_occupancy(engine, state):
    fn = partial(_occupancy, axis=None)
    jx = jax.make_jaxpr(fn)(state.spo, state.epoch, state.marked, state.rep)
    yield "occupancy", jx


# imported for its registration side effect: the fused fixpoint fns join
# the audit inventory (``fforward`` / ``fwave``) whenever the incremental
# machinery is loaded.  Must sit at module END — fused.py lazily imports
# ``_od_step`` back from this module inside its wave body.
from . import fused  # noqa: E402, F401
