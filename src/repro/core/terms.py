"""Dictionary encoding of RDF terms.

Resources are interned to dense nonzero int32 IDs (the paper: "resources are
encoded using nonzero integer resource IDs in a way that allows IDs to be used
as array indexes").  Variables in rules are encoded as *negative* integers so a
rule atom is just an int32 triple.  ID 0 is reserved as the invalid sentinel.

IDs must stay below 2**21 so a triple packs into one int64 sort key
(21 bits per position); see :mod:`repro.core.triples`.
"""

from __future__ import annotations

from typing import Iterable

# Reserved resource IDs (positions 1..N_RESERVED-1).
INVALID = 0
SAME_AS = 1          # owl:sameAs
DIFFERENT_FROM = 2   # owl:differentFrom
N_RESERVED = 3

# packing limit for int64 triple keys; the top IDs are reserved so the
# engine's KEY_MAX padding sentinel can never collide with a dictionary key.
# (Raw engine inputs may exceed MAX_ID up to 2^21-1: probes mask validity
# explicitly rather than leaning on a KEY_MAX-1 sentinel, which aliases the
# packed key of <2^21-1, 2^21-1, 2^21-2>.  The single triple whose IDs are
# ALL 2^21-1 packs to KEY_MAX itself and stays reserved — the engine never
# stores it.)
MAX_ID = (1 << 21) - 3

RESERVED_NAMES = {
    "owl:sameAs": SAME_AS,
    "owl:differentFrom": DIFFERENT_FROM,
}


class Dictionary:
    """Host-side bidirectional resource <-> ID mapping."""

    def __init__(self) -> None:
        self._to_id: dict[str, int] = dict(RESERVED_NAMES)
        self._to_name: list[str | None] = [None] * N_RESERVED
        self._to_name[SAME_AS] = "owl:sameAs"
        self._to_name[DIFFERENT_FROM] = "owl:differentFrom"

    def __len__(self) -> int:
        return len(self._to_name)

    @property
    def n_resources(self) -> int:
        return len(self._to_name)

    def intern(self, name: str) -> int:
        rid = self._to_id.get(name)
        if rid is None:
            rid = len(self._to_name)
            if rid > MAX_ID:
                raise OverflowError(
                    f"resource ID space exhausted ({rid} > {MAX_ID}); "
                    "widen the packing in triples.py"
                )
            self._to_id[name] = rid
            self._to_name.append(name)
        return rid

    def intern_many(self, names: Iterable[str]) -> list[int]:
        return [self.intern(n) for n in names]

    def lookup(self, rid: int) -> str:
        name = self._to_name[rid]
        if name is None:
            return f"_:r{rid}"
        return name

    def id_of(self, name: str) -> int:
        return self._to_id[name]

    def __contains__(self, name: str) -> bool:
        return name in self._to_id


def is_var(term: int) -> bool:
    """Variables are negative integers in the rule IR."""
    return term < 0


def var(i: int) -> int:
    """The i-th variable (i >= 1) as an IR term."""
    if i <= 0:
        raise ValueError("variable index must be >= 1")
    return -i
