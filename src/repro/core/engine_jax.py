"""Fixed-capacity JAX materialisation engine (REW mode) — the production path.

The numpy engine in :mod:`repro.core.seminaive` is the flexible reference
oracle; this module is the TPU-shaped implementation: every buffer has a
static capacity, every step is a pure jittable function, and the same round
body runs single-device or SPMD under ``shard_map`` (pass ``mesh=``).

Design (DESIGN.md §2):
  * store  = arena ``spo (CAP,3) int32`` + ``epoch (CAP,) int32`` (-1 = free,
    else the round the fact was inserted) + ``marked (CAP,) bool`` (the
    paper's outdated bit; marked facts are skipped by matching but retained),
  * delta discipline via epochs: round r matches Delta = (epoch == r-1),
    T_old = (epoch <= r-2), T_all = (epoch <= r-1),
  * joins  = sort the (small) binding table + searchsorted over packed int64
    keys with static output capacities and overflow flags (host retries with
    doubled capacity) — the arena itself is never sorted inside a round,
  * index  = a persistent sorted view of each shard's live arena rows
    (``EngineState.sort_perm``/``sorted_keys``), built once and maintained
    incrementally: fresh rows rank-merge in (:mod:`repro.kernels.merge`),
    swept/finalised rows leave via a stable partition, and a full argsort
    happens at most once per mutation epoch (capacity growth / adoption),
  * rho    = replicated representative array; merges via
    :func:`repro.core.uf.merge_pairs_jax` (min-hooking + pointer doubling),
  * rule rewriting happens on the host at the round barrier; rule *constants*
    are traced arguments, so rewriting a rule never re-traces its plan.

Distribution (the paper's N threads -> mesh ``data`` axis):
  * the arena is sharded by rows; a fact lives on shard ``subject % D``,
  * plan evaluation joins replicated bindings against the local shard and
    ``all_gather``s bindings between atoms (new sameAs pairs and candidate
    heads are few relative to the store — the paper's own observation),
  * rho is replicated and updated identically on every shard (min-hooking is
    order-independent, so no coordination is needed — the paper needed CAS),
  * candidate facts and sweep rewrites are re-routed to their owner shard by
    the gather + ownership filter (the all_to_all analogue),
  * convergence flags are psum'd.

Everything runs inside an ``enable_x64`` scope because packed triple keys
need 63 bits; inputs/outputs stay int32.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as compat_shard_map

from repro.kernels import ops as kernel_ops
from repro.kernels.merge import merge_sorted

from .rules import Program, Rule
from .stats import DispatchCounter, MatStats
from .terms import DIFFERENT_FROM, SAME_AS, is_var
from .uf import FrozenRho, compress_np, merge_pairs_jax

with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    from jax.experimental import enable_x64

I32 = jnp.int32
# numpy scalar (not jnp): module import happens outside the enable_x64 scope
KEY_MAX = np.int64((1 << 63) - 1)  # > any packed key (IDs <= MAX_ID)

# epoch predicates for matching.  PRED_OLD/DELTA/ALL drive the forward
# (derivation) rounds; PRED_TSTORE/TDELTA drive the DRed overdelete waves of
# the incremental delete path (repro.core.incremental_spmd): deletions are
# epoch-tagged *tombstones* in the ``tomb`` column (-1 = live, else the
# overdelete wave that retracted the row), and wave w matches
# Delta = (tomb == w-1) against the full pre-deletion store.
PRED_OLD, PRED_DELTA, PRED_ALL = 0, 1, 2
PRED_TSTORE, PRED_TDELTA = 3, 4


def _pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _pack3(spo: jnp.ndarray) -> jnp.ndarray:
    s = spo[..., 0].astype(jnp.int64)
    p = spo[..., 1].astype(jnp.int64)
    o = spo[..., 2].astype(jnp.int64)
    return (s << 42) | (p << 21) | o


def _pack_cols(cols: list[jnp.ndarray]) -> jnp.ndarray:
    key = jnp.zeros(cols[0].shape, dtype=jnp.int64)
    for c in cols:
        key = (key << 21) | c.astype(jnp.int64)
    return key


def _epoch_ok(
    epoch: jnp.ndarray, marked: jnp.ndarray, tomb: jnp.ndarray, r, pred: int
) -> jnp.ndarray:
    """Row-selection predicates.

    The forward predicates ignore ``tomb``: process_candidates and the
    forward rounds only ever run when every tombstone has been finalised
    into ``marked`` (the invariant kept by incremental_spmd).  The tombstone
    predicates match the *pre-deletion* store — a tombstoned row is still a
    join candidate during the backward closure, exactly like DRed matching
    deleted facts against T.
    """
    live = (epoch >= 0) & ~marked
    if pred == PRED_TSTORE:
        return live
    if pred == PRED_TDELTA:
        return live & (tomb == r - 1)
    if pred == PRED_OLD:
        return live & (epoch <= r - 2)
    if pred == PRED_DELTA:
        return live & (epoch == r - 1)
    return live & (epoch <= r - 1)


def _match_atom(spo, ok, consts, const_mask, eq_pairs):
    """const_mask/eq_pairs are static; consts is a traced (3,) int32."""
    for pos in range(3):
        if const_mask[pos]:
            ok = ok & (spo[:, pos] == consts[pos])
    for a, b in eq_pairs:
        ok = ok & (spo[:, a] == spo[:, b])
    return ok


def _compact(cols: dict, valid: jnp.ndarray, cap: int):
    """Pack valid rows to the front, truncating (or padding) at ``cap``.

    A stable partition *without sorting*: output slot ``j`` gathers the
    ``(j+1)``-th valid row, found by binary search over the inclusive
    cumsum of ``valid`` — one O(cap log n) search plus gathers, instead of
    an input-length scatter per column.  Invalid rows — and valid rows past
    ``cap``, which raise the overflow flag — are simply never gathered.
    Output rows beyond ``n_valid`` hold zeros and must stay masked by the
    returned validity.
    """
    cum = jnp.cumsum(valid)
    n_valid = cum[-1]
    j = jnp.arange(cap)
    src = jnp.clip(
        jnp.searchsorted(cum, j + 1, side="left"), 0, valid.shape[0] - 1
    )
    out_valid = j < n_valid
    out_cols = {v: jnp.where(out_valid, c[src], 0) for v, c in cols.items()}
    overflow = n_valid > cap
    return out_cols, out_valid, overflow


def _index_remove(sort_perm, sorted_keys, dead, trash):
    """Drop rows flagged ``dead`` from the sorted arena index.

    A stable partition of the surviving entries (cumsum + binary-searched
    gather, no sort — survivors keep their relative, hence sorted, order);
    freed tail slots revert to the ``trash`` row / KEY_MAX padding.
    """
    C = sorted_keys.shape[0]
    keep = (sorted_keys < KEY_MAX) & ~dead[sort_perm]
    cum = jnp.cumsum(keep)
    src = jnp.clip(
        jnp.searchsorted(cum, jnp.arange(C) + 1, side="left"), 0, C - 1
    )
    ok = jnp.arange(C) < cum[-1]
    new_perm = jnp.where(ok, sort_perm[src], trash)
    new_keys = jnp.where(ok, sorted_keys[src], KEY_MAX)
    return new_perm, new_keys


def _expand_join(
    cols, valid, spo, ok, bound_items, free_items, out_cap,
    use_kernel=False,
):
    """Join bindings against (spo, ok) on ``bound_items``; static structure.

    bound_items: list of (var, atom_pos) already present in ``cols``.
    free_items:  list of (var, atom_pos) newly bound by this atom.

    The *binding table* (bind_cap rows) is sorted — never the arena: each
    ok store row counts its matching bindings by searchsorted, and the
    output enumerates (store row, binding) pairs store-major.  Invalid
    bindings are excluded by explicit mask logic, not a key sentinel: their
    keys are forced to KEY_MAX and KEY_MAX store keys are excluded from
    counting (KEY_MAX packs only the all-max-ID triple, above ``MAX_ID`` —
    the former ``KEY_MAX - 1`` probe sentinel aliased a representable key
    at the 21-bit ID boundary).
    """
    if bound_items:
        skey = _pack_cols([spo[:, pos] for _, pos in bound_items])
        bkey = _pack_cols([cols[v] for v, _ in bound_items])
    else:
        skey = jnp.zeros(spo.shape[0], dtype=jnp.int64)
        bkey = jnp.zeros(valid.shape[0], dtype=jnp.int64)
    bkey = jnp.where(valid, bkey, KEY_MAX)
    if use_kernel:  # sort-free Pallas counting-rank dedup (same stable order)
        border = kernel_ops.dedup_order(bkey)
    else:
        border = jnp.argsort(bkey)  # bind_cap-sized — never the arena
    bkey_s = bkey[border]
    # unrolled binary search: the arena-length query side makes the scan
    # loop's per-step dispatch the dominant cost on CPU
    lo = jnp.searchsorted(bkey_s, skey, side="left", method="scan_unrolled")
    hi = jnp.searchsorted(bkey_s, skey, side="right", method="scan_unrolled")
    counts = jnp.where(ok & (skey != KEY_MAX), hi - lo, 0)
    cum = jnp.cumsum(counts) - counts  # exclusive
    total = counts.sum()
    j = jnp.arange(out_cap)
    seg = jnp.searchsorted(cum, j, side="right") - 1
    seg = jnp.clip(seg, 0, spo.shape[0] - 1)
    within = j - cum[seg]
    brow = border[jnp.clip(lo[seg] + within, 0, valid.shape[0] - 1)]
    out_valid = j < total
    new_cols = {v: jnp.where(out_valid, cols[v][brow], 0) for v in cols}
    for v, pos in free_items:
        new_cols[v] = jnp.where(out_valid, spo[seg, pos], 0)
    return new_cols, out_valid, total > out_cap, total


@dataclass(frozen=True)
class _AtomSpec:
    """Static structure of one body atom within a plan."""

    index: int
    const_mask: tuple[bool, bool, bool]
    eq_pairs: tuple[tuple[int, int], ...]
    bound_items: tuple[tuple[int, int], ...]
    free_items: tuple[tuple[int, int], ...]
    pred: int
    count_appl: bool = False  # this atom feeds the 'Rule appl.' counter


def _index_prefix(spec: _AtomSpec):
    """Static test: can this atom's join run as persistent-index range scans?

    True when the atom's *fixed* positions (constants + already-bound
    variables, including equality duplicates of bound variables) form a
    prefix of (s, p, o) — the packed-key order of the shared arena index —
    so each binding's matches are one contiguous key range.  Returns
    ``(k, components)`` with ``k`` the prefix length and ``components`` the
    per-position value source (``("const", pos)`` or ``("var", var_id)``),
    or ``(None, None)`` when the join must fall back to the generic path.
    """
    pos_src: dict[int, tuple] = {}
    for v, p in spec.bound_items:
        pos_src[p] = ("bound", v)
    for v, p in spec.free_items:
        pos_src[p] = ("free", v)
    for a, b in spec.eq_pairs:
        if a in pos_src:
            pos_src[b] = pos_src[a]
    fixed = [
        spec.const_mask[p] or pos_src.get(p, ("free",))[0] == "bound"
        for p in range(3)
    ]
    k = 0
    while k < 3 and fixed[k]:
        k += 1
    if k == 0 or any(fixed[k:]):
        return None, None
    comp = []
    for p in range(k):
        if spec.const_mask[p]:
            comp.append(("const", p))
        else:
            comp.append(("var", pos_src[p][1]))
    return k, tuple(comp)


def _atom_static(atom, bound_vars: set[int]):
    const_mask = tuple(not is_var(t) for t in atom)
    eq_pairs = []
    first_pos: dict[int, int] = {}
    for pos, t in enumerate(atom):
        if is_var(t):
            if t in first_pos:
                eq_pairs.append((first_pos[t], pos))
            else:
                first_pos[t] = pos
    bound = tuple((v, p) for v, p in first_pos.items() if v in bound_vars)
    free = tuple((v, p) for v, p in first_pos.items() if v not in bound_vars)
    return const_mask, tuple(eq_pairs), bound, free


def build_plans(
    rule: Rule, full: bool, tombstone: bool = False
) -> list[list[_AtomSpec]]:
    """Delta plans (or the single full-evaluation plan) of a rule.

    ``tombstone=True`` builds the DRed overdelete variants: the delta atom
    matches the last overdelete wave (PRED_TDELTA) and every other atom the
    full pre-deletion store (PRED_TSTORE) — the device analogue of the host
    path's ``eval_rule_delta(rule, T, T, frontier)``.
    """
    assert not (full and tombstone)
    plans = []
    delta_positions = [0] if full else list(range(len(rule.body)))
    for i in delta_positions:
        specs = []
        bound: set[int] = set()
        for j, atom in enumerate(rule.body):
            const_mask, eq_pairs, b, f = _atom_static(atom, bound)
            if full:
                pred = PRED_ALL
            else:
                pred = PRED_OLD if j < i else (PRED_DELTA if j == i else PRED_ALL)
            if tombstone:
                pred = PRED_TDELTA if pred == PRED_DELTA else PRED_TSTORE
            count_appl = not tombstone and (
                (pred == PRED_DELTA) or (full and j == 0)
            )
            specs.append(_AtomSpec(j, const_mask, eq_pairs, b, f, pred, count_appl))
            bound |= {v for v, _ in b} | {v for v, _ in f}
        plans.append(specs)
    return plans


def _expand_join_index(
    cols, valid, spo, epoch, marked, tomb, r, sorted_keys, sort_perm,
    consts, spec: "_AtomSpec", k: int, comp: tuple, out_cap: int,
):
    """Index-backed variant of :func:`_expand_join` for prefix-key atoms.

    Each binding's matches in the live store are one contiguous range of
    the persistent sorted index (``[pack(prefix, 0..), pack(prefix, max..)]``),
    so the join is two ``searchsorted`` calls *per binding table* plus the
    output enumeration — O(bind log C + out) with no arena-length
    intermediate at all.  Only used for predicates satisfied by every live
    row (PRED_ALL at evaluation round, PRED_TSTORE), so range counts are
    exact up to intra-atom equality duplicates, which the post-filter
    clears (they only cost masked output slots, never correctness).
    """
    maxid = jnp.int64((1 << 21) - 1)
    lo_parts, hi_parts = [], []
    for p in range(3):
        if p < k:
            src, ref = comp[p]
            if src == "const":
                col = jnp.broadcast_to(
                    consts[ref].astype(jnp.int64), valid.shape
                )
            else:
                col = cols[ref].astype(jnp.int64)
            lo_parts.append(col)
            hi_parts.append(col)
        else:
            lo_parts.append(jnp.zeros(valid.shape, jnp.int64))
            hi_parts.append(jnp.broadcast_to(maxid, valid.shape))
    lokey = _pack_cols(lo_parts)
    hikey = _pack_cols(hi_parts)
    lo = jnp.searchsorted(sorted_keys, lokey, side="left")
    hi = jnp.searchsorted(sorted_keys, hikey, side="right")
    counts = jnp.where(valid, jnp.maximum(hi - lo, 0), 0)
    cum = jnp.cumsum(counts) - counts  # exclusive
    total = counts.sum()
    j = jnp.arange(out_cap)
    seg = jnp.searchsorted(cum, j, side="right") - 1
    seg = jnp.clip(seg, 0, valid.shape[0] - 1)
    within = j - cum[seg]
    srow = sort_perm[jnp.clip(lo[seg] + within, 0, sort_perm.shape[0] - 1)]
    out_valid = j < total
    rows = spo[srow]
    okr = _epoch_ok(epoch[srow], marked[srow], tomb[srow], r, spec.pred)
    okr = _match_atom(rows, okr, consts, spec.const_mask, spec.eq_pairs)
    out_valid = out_valid & okr
    new_cols = {v: jnp.where(out_valid, cols[v][seg], 0) for v in cols}
    for v, pos in spec.free_items:
        new_cols[v] = jnp.where(out_valid, rows[:, pos], 0)
    return new_cols, out_valid, total > out_cap


def _gather(x, axis):
    return jax.lax.all_gather(x, axis, tiled=True)


def _route_rows(stream, flags, valid, axis, n_shards, route_cap):
    """Owner-route an (N, 3) triple stream to shard ``subject % n_shards``.

    The bulk analogue of the paper's per-thread insertion into the shared
    store, shared by process_candidates and the incremental delete path
    (tombstone waves): each shard routes every row to its owner with one
    ``all_to_all`` of (n_shards, route_cap) buckets.  ``flags`` is an
    optional (N, k) int32 array of side columns that ride along with the
    rows.  Returns ``(stream', flags', valid', overflow)``:

      * ``axis is None`` — identity (single device),
      * ``route_cap is None`` — all-gather fallback: every shard sees the
        global stream, masked down to the rows it owns,
      * otherwise — bucket exchange; per-destination overflow beyond
        ``route_cap`` raises the engine's capacity-retry via the flag.
    """
    if axis is None:
        return stream, flags, valid, jnp.zeros((), bool)
    if route_cap is None:
        me = jax.lax.axis_index(axis)
        stream = _gather(stream, axis)
        flags = _gather(flags, axis) if flags is not None else None
        valid = _gather(valid, axis)
        own = (stream[:, 0] % n_shards).astype(I32) == me
        return stream, flags, valid & own, jnp.zeros((), bool)
    k = 0 if flags is None else flags.shape[1]
    owner = (stream[:, 0] % n_shards).astype(I32)
    okey = jnp.where(valid, owner, n_shards)
    order = jnp.argsort(okey, stable=True).astype(I32)
    so = okey[order]
    starts = jnp.searchsorted(so, jnp.arange(n_shards, dtype=I32)).astype(I32)
    pos = jnp.arange(so.shape[0], dtype=I32) - starts[jnp.clip(so, 0, n_shards - 1)]
    keep = (so < n_shards) & (pos < route_cap)
    overflow = jnp.any((so < n_shards) & (pos >= route_cap))
    cols = [stream[order]]
    if flags is not None:
        cols.append(flags[order])
    cols.append(keep[:, None].astype(I32))
    payload = jnp.concatenate(cols, axis=1)  # (N, 3 + k + 1)
    buckets = jnp.zeros((n_shards, route_cap, 3 + k + 1), I32)
    tgt_shard = jnp.where(keep, so, 0)
    tgt_slot = jnp.where(keep, pos, route_cap)  # out-of-range -> dropped
    buckets = buckets.at[tgt_shard, tgt_slot].set(
        jnp.where(keep[:, None], payload, 0), mode="drop"
    )
    recv = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0, tiled=True)
    out_stream = recv[..., :3].reshape(-1, 3)
    out_flags = recv[..., 3 : 3 + k].reshape(-1, k) if flags is not None else None
    out_valid = recv[..., 3 + k].reshape(-1).astype(bool)
    return out_stream, out_flags, out_valid, overflow


def eval_plan(
    spo,
    epoch,
    marked,
    tomb,
    sorted_keys,
    sort_perm,
    r,
    atom_consts,  # (n_atoms, 3) traced rule constants (vars hold garbage 0)
    head_consts,  # (3,) traced
    plan: tuple,  # static tuple of _AtomSpec
    head_var_slots: tuple,  # static: per head position, var id or None
    bind_cap: int,
    out_cap: int,
    axis: str | None = None,
    use_kernel: bool = False,
):
    """Evaluate one delta plan; returns (heads (out_cap,3), valid, stats...).

    Under SPMD (``axis`` set): each atom joins against the *local* store
    shard; bindings are all_gathered between atoms so every shard sees the
    global binding table.  The final join's results stay local — their union
    over shards is the global candidate set.

    Atoms whose fixed positions form a packed-key prefix and whose
    predicate admits every live row (PRED_ALL / PRED_TSTORE) join through
    the persistent sorted index (:func:`_expand_join_index`) — range scans
    instead of any arena-length intermediate; the rest take the generic
    bindings-sorting join.
    """
    cols: dict[int, jnp.ndarray] = {}
    valid = jnp.ones((1,), dtype=bool)  # the unit binding
    n_appl = jnp.zeros((), I32)
    overflow = jnp.zeros((), bool)
    for step, spec in enumerate(plan):
        is_join = not (step == 0 and not spec.bound_items)
        if spec.count_appl or not is_join:
            ok = _epoch_ok(epoch, marked, tomb, r, spec.pred)
            ok = _match_atom(
                spo, ok, atom_consts[spec.index], spec.const_mask, spec.eq_pairs
            )
            if spec.count_appl:
                n_appl = n_appl + ok.sum().astype(I32)
        if not is_join:
            # initial scan: bindings = matching rows directly (no join needed)
            cols = {v: jnp.where(ok, spo[:, p], 0) for v, p in spec.free_items}
            valid = ok
            cols, valid, ov = _compact(cols, valid, bind_cap)
        else:
            cols, valid, ov = _join_step(
                cols, valid, spo, epoch, marked, tomb, r,
                sorted_keys, sort_perm, atom_consts[spec.index], spec, bind_cap,
                use_kernel=use_kernel,
            )
        overflow |= ov
        if axis is not None and step < len(plan) - 1:
            cols = {v: _gather(c, axis) for v, c in cols.items()}
            valid = _gather(valid, axis)
    out, out_valid, n_deriv, ov = _emit_heads(
        cols, valid, head_consts, head_var_slots, out_cap
    )
    # bind and out overflow reported separately so the host retry can grow
    # exactly the capacity that was exhausted
    return out, out_valid, n_deriv[None], n_appl[None], overflow[None], ov[None]


def _join_step(
    cols, valid, spo, epoch, marked, tomb, r, sorted_keys, sort_perm,
    consts, spec: _AtomSpec, bind_cap: int, use_kernel: bool = False,
):
    """One join step of a plan, shared by :func:`eval_plan` and
    :func:`eval_plan_rederive`: an atom whose fixed positions form a
    packed-key prefix and whose predicate admits every live row
    (PRED_ALL / PRED_TSTORE) runs as index range scans; the rest take the
    generic bindings-sorting join.  Returns ``(cols, valid, overflow)``.
    """
    if spec.pred in (PRED_ALL, PRED_TSTORE):
        k, comp = _index_prefix(spec)
        if k is not None:
            return _expand_join_index(
                cols, valid, spo, epoch, marked, tomb, r,
                sorted_keys, sort_perm, consts, spec, k, comp, bind_cap,
            )
    ok = _epoch_ok(epoch, marked, tomb, r, spec.pred)
    ok = _match_atom(spo, ok, consts, spec.const_mask, spec.eq_pairs)
    cols, valid, ov, _ = _expand_join(
        cols, valid, spo, ok, spec.bound_items, spec.free_items, bind_cap,
        use_kernel=use_kernel,
    )
    return cols, valid, ov


def _emit_heads(cols, valid, head_consts, head_var_slots: tuple, out_cap: int):
    """Instantiate the head pattern over a binding table and compact it to
    the output buffer; returns ``(out, out_valid, n_deriv, overflow)``."""
    heads = []
    for pos in range(3):
        v = head_var_slots[pos]
        if v is None:
            heads.append(jnp.broadcast_to(head_consts[pos], valid.shape).astype(I32))
        else:
            heads.append(cols[v].astype(I32))
    out = jnp.stack(heads, axis=1)
    outc, out_valid, ov = _compact(
        {"s": out[:, 0], "p": out[:, 1], "o": out[:, 2]}, valid, out_cap
    )
    out = jnp.stack([outc["s"], outc["p"], outc["o"]], axis=1)
    return out, out_valid, out_valid.sum().astype(I32), ov


def build_rederive_plan(rule: Rule) -> tuple[list[_AtomSpec], tuple[int, ...]]:
    """The single head-bound plan of a rule for targeted rederivation.

    Delete-side rederivation only ever needs to restore *overdeleted* head
    instances, so instead of evaluating the whole rule against the surviving
    store the join is chained backward from the head: the head variables are
    pre-bound (to the overdeleted instances — see
    ``incremental_spmd._head_bindings``) and every body atom matches the
    surviving live store (``PRED_TSTORE``).  Body atoms are greedily
    reordered so each step shares a variable with the already-bound set
    where possible — bound positions then form packed-key prefixes and the
    join runs as range queries on the persistent sorted index.

    Returns ``(specs, head_vars)`` where ``head_vars`` is the head's
    first-occurrence variable order — the column order the seed table must
    use (``_AtomSpec.index`` keeps the original atom index for constant
    lookup).
    """
    head_vars = tuple(dict.fromkeys(t for t in rule.head if is_var(t)))
    remaining = list(range(len(rule.body)))
    bound: set[int] = set(head_vars)
    specs: list[_AtomSpec] = []
    while remaining:
        j = next(
            (i for i in remaining
             if any(is_var(t) and t in bound for t in rule.body[i])),
            remaining[0],
        )
        remaining.remove(j)
        const_mask, eq_pairs, b, f = _atom_static(rule.body[j], bound)
        specs.append(_AtomSpec(j, const_mask, eq_pairs, b, f, PRED_TSTORE))
        bound |= {v for v, _ in b} | {v for v, _ in f}
    return specs, head_vars


def eval_plan_rederive(
    spo,
    epoch,
    marked,
    tomb,
    sorted_keys,
    sort_perm,
    atom_consts,  # (n_atoms, 3) traced rule constants (vars hold garbage 0)
    head_consts,  # (3,) traced
    seeds,        # (seed_cap, n_seed_vars) replicated head-variable bindings
    seed_valid,   # (seed_cap,) replicated
    plan: tuple,  # static tuple of _AtomSpec from build_rederive_plan
    head_var_slots: tuple,
    seed_vars: tuple,  # static: variable id per seed column
    bind_cap: int,
    out_cap: int,
    axis: str | None = None,
    use_kernel: bool = False,
):
    """Head-bound rederivation join; returns (heads, valid, n_deriv, ovs...).

    The binding table starts from the replicated seed columns instead of an
    arena scan, so every join intermediate — and every sort — scales with
    the overdelete delta, never with the surviving arena.  Atoms whose fixed
    positions form a packed-key prefix probe the persistent sorted index
    (:func:`_expand_join_index`); the rest take the generic
    bindings-sorting join.  Mirrors :func:`eval_plan`'s SPMD discipline:
    bindings are all_gathered between atoms, the final join's results stay
    local.
    """
    r = jnp.zeros((), I32)  # PRED_TSTORE ignores the round counter
    cols = {v: seeds[:, i].astype(I32) for i, v in enumerate(seed_vars)}
    valid = seed_valid
    overflow = jnp.zeros((), bool)
    for step, spec in enumerate(plan):
        cols, valid, ov = _join_step(
            cols, valid, spo, epoch, marked, tomb, r,
            sorted_keys, sort_perm, atom_consts[spec.index], spec, bind_cap,
            use_kernel=use_kernel,
        )
        overflow |= ov
        if axis is not None and step < len(plan) - 1:
            cols = {v: _gather(c, axis) for v, c in cols.items()}
            valid = _gather(valid, axis)
    out, out_valid, n_deriv, ov_out = _emit_heads(
        cols, valid, head_consts, head_var_slots, out_cap
    )
    return out, out_valid, n_deriv[None], overflow[None], ov_out[None]


def classify_remerge(rule_old: Rule, rule_new: Rule):
    """How to re-evaluate one rule whose constants a rho re-merge rewrote.

    Returns ``("skip", None)``, ``("anchor", j)`` or ``("full", None)``:

    * ``"skip"`` — only the head changed.  The body is unchanged, so the
      match set is exactly the one already enumerated under the old
      spelling, and the sweep re-normalises the stored head instances under
      the new rho; nothing needs evaluating.
    * ``("anchor", j)`` — body atom ``j`` changed and has at least one
      variable: evaluate the single merge-targeted plan of
      :func:`build_merge_plan` anchored there.  Among changed variable
      atoms the anchor is the one sharing the most variables with the rest
      of the body (ties to the earliest atom), so the chained joins stay
      bound-first.
    * ``"full"`` — every changed body atom is variable-free.  A ground
      anchor contributes no binding columns, so the remaining atoms would
      chain as unconstrained cross-products at delta widths — strictly
      worse than the wide-buffer full plan.  Whole-rule requeue.
    """
    changed = [
        j for j, (a, b) in enumerate(zip(rule_old.body, rule_new.body))
        if a != b
    ]
    if not changed:
        return "skip", None
    scored = []
    for j in changed:
        vs = {t for t in rule_new.body[j] if is_var(t)}
        if not vs:
            continue
        rest = {
            t for i, atom in enumerate(rule_new.body) if i != j
            for t in atom if is_var(t)
        }
        scored.append((len(vs & rest), -j))
    if not scored:
        return "full", None
    _, neg_j = max(scored)
    return "anchor", -neg_j


def build_merge_plan(rule: Rule, anchor: int) -> list[_AtomSpec]:
    """The single merge-targeted plan of a rule a rho re-merge rewrote.

    A re-merge creates new matches in two disjoint ways: matches using at
    least one row of the merge round's fresh delta (the sweep re-inserts
    every rewritten spelling as a fresh row, so the ordinary delta plans of
    the rewritten program cover those), and matches whose rows are ALL
    pre-merge.  An all-old match that is new must place an old row at a
    *changed* atom — under the old spelling that row could not have
    matched — so scanning one changed atom (the anchor) against the
    pre-merge store (``PRED_OLD``) and chaining the remaining atoms through
    the live store (``PRED_ALL``) enumerates a superset of the new all-old
    matches.  The anchor's rewritten constant keeps that scan narrow (rows
    touching the merged representative), which is the point: the whole-rule
    full plan this replaces opens with an unconstrained store-wide scan.

    Remaining atoms are ordered greedily bound-first (exactly like
    :func:`build_rederive_plan`) so bound positions form packed-key
    prefixes for the persistent sorted index.
    """
    const_mask, eq_pairs, b, f = _atom_static(rule.body[anchor], set())
    specs = [_AtomSpec(anchor, const_mask, eq_pairs, b, f, PRED_OLD, True)]
    bound = {v for v, _ in b} | {v for v, _ in f}
    remaining = [j for j in range(len(rule.body)) if j != anchor]
    while remaining:
        j = next(
            (i for i in remaining
             if any(is_var(t) and t in bound for t in rule.body[i])),
            remaining[0],
        )
        remaining.remove(j)
        const_mask, eq_pairs, b, f = _atom_static(rule.body[j], bound)
        specs.append(_AtomSpec(j, const_mask, eq_pairs, b, f, PRED_ALL))
        bound |= {v for v, _ in b} | {v for v, _ in f}
    return specs


def process_candidates(
    spo,
    epoch,
    marked,
    n_used,
    rep,
    sort_perm,
    sorted_keys,
    cands,
    cand_valid,
    r,
    rewrite_cap: int,
    axis: str | None = None,
    n_shards: int = 1,
    route_cap: int | None = None,
    pair_cap: int = 4096,
    use_kernel: bool = False,
    delta_window: int = 4096,
):
    """Normalise, merge equalities, sweep, insert — the state-update half of a
    round (Algorithms 3-6 in bulk).  Pure; runs per-shard under shard_map.

    ``sort_perm``/``sorted_keys`` is the persistent sorted index of the
    shard's live rows; it is consumed by the membership probe and returned
    up to date — swept rows leave via a stable partition, fresh rows (whose
    keys the dedup step already sorted) rank-merge in.  No step here sorts
    the arena.

    Under SPMD there are two exchange schemes:

      * ``route_cap=None`` (baseline): candidates are ALL-GATHERED so every
        shard sees/sorts the global padded stream; an ownership mask
        (``subject % n_shards``) picks the inserting shard.  The per-shard
        sort is O(n_shards x out_cap x 4) — 33.5M rows on the 256-chip
        round_268m cell, 99% padding (measured, §Perf).
      * ``route_cap=k`` (owner routing — the bulk analogue of the paper's
        per-thread insertion into the shared store): each shard expands its
        OWN candidates (rewrites + reflexivity), then routes every row to
        its owner with one all_to_all of (n_shards, k) buckets.  Only the
        few global sameAs pairs are still all-gathered (rho must update
        identically everywhere).  Per-shard sort shrinks to
        n_shards x route_cap rows and the exchange moves bucket payloads
        instead of the padded stream.  Bucket overflow raises the engine's
        capacity-retry (host doubles ``route_cap``).
    """
    arena_cap = spo.shape[0] - 1  # last row is the scatter trash slot
    n_used = n_used.reshape(())
    routed = axis is not None and route_cap is not None
    route_overflow = jnp.zeros((), bool)
    pair_overflow = jnp.zeros((), bool)

    if axis is not None and not routed:
        cands = _gather(cands, axis)
        cand_valid = _gather(cand_valid, axis)

    # 1) normalise with current rho
    cands = jnp.where(cand_valid[:, None], rep[cands], 0).astype(I32)

    # 2) merge sameAs pairs (deterministic min-hooking -> identical on shards)
    is_pair = cand_valid & (cands[:, 1] == SAME_AS) & (cands[:, 0] != cands[:, 2])
    if routed:
        # pairs are few: compact locally, gather the compacted buffer
        n_pairs = jax.lax.psum(is_pair.sum().astype(I32), axis)
        pcols, pvalid, p_ov = _compact(
            {"a": cands[:, 0], "b": cands[:, 2]}, is_pair, pair_cap
        )
        pair_overflow |= p_ov
        pairs = _gather(jnp.stack([pcols["a"], pcols["b"]], axis=1), axis)
        pair_valid = _gather(pvalid, axis)
    else:
        pairs = jnp.stack([cands[:, 0], cands[:, 2]], axis=1)
        pair_valid = is_pair
        n_pairs = is_pair.sum().astype(I32)
    new_rep = merge_pairs_jax(rep, pairs, pair_valid)
    rep_changed = jnp.any(new_rep != rep)
    rep = new_rep

    # 3) re-normalise candidates under the new rho
    cands = jnp.where(cand_valid[:, None], rep[cands], 0).astype(I32)

    # 4) sweep the local store shard (bulk Algorithm 3).  Most steady-state
    # rounds sweep nothing (rho unchanged), so the compaction and the index
    # partition sit behind a ``cond`` — XLA only runs the taken branch,
    # turning the arena-wide scatter work into a no-op on quiet rounds.
    live = (epoch >= 0) & ~marked
    rewritten = rep[spo].astype(I32)
    changed = live & jnp.any(rewritten != spo, axis=1)
    marked = marked | changed

    def _do_sweep(_):
        rw_cols, rw_valid, rw_overflow = _compact(
            {"s": rewritten[:, 0], "p": rewritten[:, 1], "o": rewritten[:, 2]},
            changed,
            rewrite_cap,
        )
        rw = jnp.stack([rw_cols["s"], rw_cols["p"], rw_cols["o"]], axis=1)
        # swept rows leave the persistent index (stable partition, no sort)
        perm, keys = _index_remove(sort_perm, sorted_keys, changed, arena_cap)
        return rw, rw_valid, rw_overflow, perm, keys

    def _no_sweep(_):
        return (
            jnp.zeros((rewrite_cap, 3), I32), jnp.zeros((rewrite_cap,), bool),
            jnp.zeros((), bool), sort_perm, sorted_keys,
        )

    rw, rw_valid, rw_overflow, sort_perm, sorted_keys = jax.lax.cond(
        changed.any(), _do_sweep, _no_sweep, 0
    )
    if axis is not None and not routed:
        rw = _gather(rw, axis)
        rw_valid = _gather(rw_valid, axis)

    all_c = jnp.concatenate([cands, rw], axis=0)
    all_v = jnp.concatenate([cand_valid, rw_valid], axis=0)

    # 5) contradiction check (~=5) on normal forms — pre-ownership, so every
    # shard reports the same verdict
    contradiction = jnp.any(
        all_v & (all_c[:, 1] == DIFFERENT_FROM) & (all_c[:, 0] == all_c[:, 2])
    )
    if routed:  # local verdicts -> identical global verdict
        contradiction = jax.lax.psum(contradiction.astype(I32), axis) > 0

    # 6) reflexivity (Algorithm 4 lines 17-18): <c, sameAs, c> for each
    # resource of each candidate, plus <sameAs,sameAs,sameAs>
    res = all_c.reshape(-1)
    res_valid = jnp.repeat(all_v, 3)
    refl = jnp.stack([res, jnp.full_like(res, SAME_AS), res], axis=1)
    sa_row = jnp.asarray([[SAME_AS, SAME_AS, SAME_AS]], dtype=I32)
    any_v = jnp.any(all_v)
    stream = jnp.concatenate([all_c, refl, sa_row], axis=0)
    stream_v = jnp.concatenate([all_v, res_valid, any_v[None]], axis=0)
    # origin flag: True for rows created by the reflexivity expansion (so a
    # rule-derived reflexive fact is booked as a rule derivation, not here;
    # stable sort keeps the candidate occurrence on duplicates)
    stream_refl = jnp.concatenate(
        [jnp.zeros(all_c.shape[0], bool), jnp.ones(res.shape[0] + 1, bool)]
    )

    # ownership: a row is inserted only by shard ``subject % n_shards``
    if routed:
        # route rows to their owners: one all_to_all of (n_shards, route_cap)
        # buckets replaces sorting the global padded stream on every shard
        stream, refl_col, stream_v, r_ov = _route_rows(
            stream, stream_refl[:, None].astype(I32), stream_v,
            axis, n_shards, route_cap,
        )
        stream_refl = refl_col[:, 0].astype(bool)
        route_overflow |= r_ov
    elif axis is not None:
        own = (stream[:, 0] % n_shards) == jax.lax.axis_index(axis)
        stream_v = stream_v & own

    # 7) dedup within the stream
    skeys = jnp.where(stream_v, _pack3(stream), KEY_MAX)
    if use_kernel:  # sort-free Pallas counting-rank dedup (same stable order)
        order = kernel_ops.dedup_order(skeys)
    else:
        order = jnp.argsort(skeys, stable=True)
    sk = skeys[order]
    uniq = jnp.concatenate([jnp.asarray([True]), sk[1:] != sk[:-1]])
    uniq = uniq & (sk < KEY_MAX)

    # 8) membership against live local store rows: probe the persistent
    # sorted index instead of re-sorting the arena
    pos = jnp.clip(jnp.searchsorted(sorted_keys, sk), 0, sorted_keys.shape[0] - 1)
    member = sorted_keys[pos] == sk
    fresh = uniq & ~member

    # 9) scatter fresh rows into free local slots
    n_fresh = fresh.sum().astype(I32)
    slot = n_used + jnp.cumsum(fresh) - 1
    insert_overflow = (n_used + n_fresh) > arena_cap
    tgt = jnp.where(fresh, jnp.minimum(slot, arena_cap), arena_cap)
    rows = stream[order]
    spo = spo.at[tgt].set(jnp.where(fresh[:, None], rows, spo[tgt]))
    epoch = epoch.at[tgt].set(jnp.where(fresh, r, epoch[tgt]))
    # the trash row must stay dead no matter what was scattered into it
    spo = spo.at[arena_cap].set(0)
    epoch = epoch.at[arena_cap].set(-1)
    n_used = n_used + n_fresh

    # 9b) merge the fresh delta into the sorted index: ``sk`` is ascending,
    # so compacting the fresh (key, slot, row) tuples (stable, no sort)
    # yields a sorted delta that rank-merges into the index in O(C) gather
    # work — the full-arena argsort this replaces was the round loop's
    # single biggest cost on sort-bound backends.  Like the sweep above,
    # the merge sits behind a ``cond`` so rounds that inserted nothing
    # (every operation's final convergence round) skip the arena-length
    # work entirely.
    dcols, dvalid, _ = _compact(
        {
            "k": sk, "v": tgt.astype(I32),
            "s": rows[:, 0], "p": rows[:, 1], "o": rows[:, 2],
        },
        fresh, sk.shape[0],
    )

    def _do_merge(_):
        d_keys = jnp.where(dvalid, dcols["k"], KEY_MAX)
        d_vals = jnp.where(dvalid, dcols["v"], arena_cap).astype(I32)
        return merge_sorted(
            sorted_keys, sort_perm, d_keys, d_vals,
            out_len=sorted_keys.shape[0],
        )

    sorted_keys, sort_perm = jax.lax.cond(
        n_fresh > 0, _do_merge, lambda _: (sorted_keys, sort_perm), 0
    )

    # reflexive-added stat: fresh rows originating from the reflexivity step
    is_refl = fresh & stream_refl[order]
    n_refl = is_refl.sum().astype(I32)

    # the compacted fresh delta rides back to the host, which derives the
    # per-position resource masks for dead-plan elimination there — a few
    # delta rows of numpy work instead of per-round arena-length scatters
    # and a psum on the device.  Truncated to a bounded width so the
    # per-round device-to-host transfer never scales with a wide padded
    # stream; on overflow (n_new exceeds the window) the host falls back
    # to all-True masks, which skip nothing and stay sound.
    d_window = min(sk.shape[0], delta_window)
    delta_rows = jnp.stack(
        [dcols["s"][:d_window], dcols["p"][:d_window], dcols["o"][:d_window]],
        axis=1,
    )

    flags = {
        "rep_changed": rep_changed,
        "contradiction": contradiction,
        "ov_rewrite": rw_overflow[None],
        "ov_store": insert_overflow[None],
        "ov_route": route_overflow[None],
        "ov_pair": pair_overflow[None],
        "n_new": n_fresh[None],
        "n_pairs": n_pairs,
        "n_marked": changed.sum().astype(I32)[None],
        "n_reflexive": n_refl[None],
        "delta_rows": delta_rows,
        "delta_valid": dvalid[:d_window],
    }
    return spo, epoch, marked, n_used[None], rep, sort_perm, sorted_keys, flags


class CapacityError(RuntimeError):
    pass


def index_invariant_report(state: "EngineState", n_shards: int = 1) -> list[str]:
    """Violations of the persistent-index invariant (empty == healthy).

    Per shard block: ``sorted_keys`` must hold exactly the packed keys of
    the live rows, sorted ascending, as a prefix followed by KEY_MAX
    padding, and ``sort_perm``'s prefix must enumerate exactly those rows.
    Host-side diagnostic shared by the invariant fuzz tests and debugging;
    states whose index is marked dirty (pending rebuild) are reported as
    such rather than checked.
    """
    from .triples import pack  # host-side numpy packing (same bit layout)

    if state.index_dirty:
        return ["index_dirty: rebuild pending"]
    probs: list[str] = []
    spo = np.asarray(state.spo).reshape(n_shards, -1, 3)
    epoch = np.asarray(state.epoch).reshape(n_shards, -1)
    marked = np.asarray(state.marked).reshape(n_shards, -1)
    keys = np.asarray(state.sorted_keys).reshape(n_shards, -1)
    perm = np.asarray(state.sort_perm).reshape(n_shards, -1)
    for s in range(n_shards):
        live = (epoch[s] >= 0) & ~marked[s]
        want = np.sort(pack(spo[s][live]))
        n = want.shape[0]
        if not (keys[s][n:] == KEY_MAX).all():
            probs.append(f"shard {s}: non-sentinel entries beyond live prefix")
        if not np.array_equal(keys[s][:n], want):
            probs.append(f"shard {s}: sorted_keys != sort(pack3(live rows))")
        if not np.array_equal(np.sort(perm[s][:n]), np.flatnonzero(live)):
            probs.append(f"shard {s}: sort_perm prefix is not the live row set")
        got = pack(spo[s][perm[s][:n]])
        if not np.array_equal(got, keys[s][:n]):
            probs.append(f"shard {s}: sort_perm rows disagree with sorted_keys")
    return probs


@dataclass
class EngineState:
    """Device-resident materialisation state that survives update batches.

    The arena columns live sharded on the mesh; ``rep`` is replicated;
    ``explicit`` is the current explicit fact set (host, original IDs) and
    ``r`` the running round counter — epochs keep increasing across updates
    so the delta discipline of :func:`_epoch_ok` carries over unchanged.
    ``tomb`` is -1 everywhere except inside a delete operation's backward
    pass (see :mod:`repro.core.incremental_spmd`).

    ``sort_perm``/``sorted_keys`` is the **persistent sorted arena index**:
    per shard block, ``sorted_keys`` holds the packed int64 keys of exactly
    the live (``epoch >= 0 & ~marked``) rows in ascending order (KEY_MAX
    padding behind) and ``sort_perm`` the local row index of each entry.
    Every membership probe — store insertion, tombstone seeding/waves,
    rederive seeds, serving snapshots — binary-searches this shared view;
    it is maintained *incrementally* (rank-merge on insert, stable
    partition on sweep/finalize), so the arena is argsorted at most once
    per mutation epoch: ``index_dirty`` marks the rare rebuild points
    (capacity growth re-layout) and
    :meth:`JaxEngine._ensure_index` pays the sort lazily at the next
    operation's start.
    """

    spo: jnp.ndarray
    epoch: jnp.ndarray
    marked: jnp.ndarray
    tomb: jnp.ndarray
    n_used: jnp.ndarray
    rep: jnp.ndarray
    sort_perm: jnp.ndarray
    sorted_keys: jnp.ndarray
    program: Program
    base_program: Program
    explicit: np.ndarray
    r: int
    stats: MatStats
    # maintenance-epoch counter: number of COMPLETED update operations since
    # the base fixpoint (which is epoch 0).  Distinct from ``r``/``epoch``
    # (the per-round delta discipline): readers version themselves on this,
    # and it only ever advances at an epoch barrier — never mid-operation.
    update_epoch: int = 0
    # True when sort_perm/sorted_keys no longer describe the arena (set on
    # capacity re-layout); cleared by JaxEngine._ensure_index
    index_dirty: bool = False

    @property
    def n_res(self) -> int:
        return int(self.rep.shape[0])


class StoreSnapshot:
    """Immutable, epoch-consistent read view of an :class:`EngineState`.

    Published at epoch barriers only — after a maintenance operation's
    fixpoint completes, never mid-round — so a query evaluated against a
    snapshot observes exactly the fixpoint of maintenance epoch ``epoch``:
    no tombstoned-but-not-yet-rederived rows, no half-applied clique split.
    ``rho`` is the frozen representative view whose clique tables are shared
    by every query answered at this epoch (the serving contract of
    :mod:`repro.serve.triple_store`; docs/serving.md).

    Two backing forms:

      * **host** — ``triples`` is an eager host copy of the live
        normal-form store (:meth:`JaxEngine.read_snapshot`, and the SPMD
        path, build these);
      * **device-resident** (:meth:`JaxEngine.publish_snapshot`) — the
        live rows stay on the accelerator in TWO sorted orders: ``(s,p,o)``
        packed-key order (``d_triples``/``d_keys``) and ``(p,o,s)`` order
        (``d_triples_pos``/``d_keys_pos``), each padded to the arena width
        with KEY_MAX keys behind the ``n_live`` live rows.  The batched
        query executor (:mod:`repro.sparql.batched`) range-probes these
        directly, so serving a query costs no device->host copy at all;
        ``triples`` is materialised to host lazily, only when a
        non-batchable query falls back to the host matcher.

    Both forms are immutable: device arrays are never written after
    publication (the double-buffer swap retires, never mutates, the
    previous epoch's buffers) and the host copy is marked read-only.
    """

    __slots__ = (
        "epoch", "rho", "_triples", "n_live",
        "d_triples", "d_keys", "d_triples_pos", "d_keys_pos",
    )

    def __init__(
        self, epoch: int, rho: FrozenRho, triples: np.ndarray | None = None,
        device: tuple | None = None,
    ) -> None:
        self.epoch = epoch
        self.rho = rho
        self._triples = triples
        if device is not None:
            (self.d_triples, self.d_keys, self.d_triples_pos,
             self.d_keys_pos, self.n_live) = device
        else:
            self.d_triples = self.d_keys = None
            self.d_triples_pos = self.d_keys_pos = None
            self.n_live = None if triples is None else int(triples.shape[0])

    @property
    def on_device(self) -> bool:
        return self.d_keys is not None

    @property
    def triples(self) -> np.ndarray:
        """Host copy of the normal-form store (lazy for device snapshots)."""
        if self._triples is None:
            t = np.asarray(self.d_triples)[: self.n_live]
            t.setflags(write=False)
            self._triples = t
        return self._triples

    @property
    def n_res(self) -> int:
        return len(self.rho)


# -- auditable-fn registry (repro.analysis) ---------------------------------
#
# Every compiled fn family the engine dispatches registers a *trace builder*
# here: ``builder(engine, state)`` yields ``(label, jaxpr)`` pairs covering
# the family's variants at the caller's probe geometry.  ``repro.analysis``
# runs its invariant passes over the full registry — a new hot fn that does
# not register is caught by the dispatch cross-check instead (its runtime
# family shows up in no phase profile).  ``skip_passes`` names passes whose
# invariant the family is deliberately exempt from (each exemption is a
# documented cost decision, not a loophole — see docs/analysis.md).

@dataclass(frozen=True)
class AuditableFn:
    name: str
    builder: callable
    skip_passes: tuple = ()


AUDIT_REGISTRY: dict[str, AuditableFn] = {}


def register_auditable(name: str, skip_passes: tuple = ()):
    def deco(builder):
        AUDIT_REGISTRY[name] = AuditableFn(name, builder, tuple(skip_passes))
        return builder

    return deco


def _rebuild_index(spo, epoch, marked):
    """Full index rebuild: the ONE allowed arena argsort (per mutation epoch)."""
    live = (epoch >= 0) & ~marked
    keys = jnp.where(live, _pack3(spo), KEY_MAX)
    perm = jnp.argsort(keys)
    return perm.astype(I32), keys[perm]


def _publish_snapshot(spo, sort_perm, sorted_keys):
    """Device-resident snapshot build — the per-barrier publication step.

    Gathers the live rows through the persistent sorted index (one gather:
    the ``(s,p,o)``-ordered view is the index itself) and derives the
    secondary ``(p,o,s)``-ordered view with ONE argsort — the only sort the
    publication pays, off the query path entirely (the NoArenaSort
    exemption mirrors ``rebuild_index``: a deliberate, counted, per-epoch
    cost — see docs/serving.md).  The two orders make every atom whose
    bound positions prefix either ``(s,p,o)`` or ``(p,o,s)`` a contiguous
    range probe for the batched query executor.  Returns
    ``(tri, keys, tri_pos, keys_pos, n_live)``; padding rows carry KEY_MAX
    keys behind the live prefix.
    """
    tri = spo[sort_perm]
    live = sorted_keys < KEY_MAX
    n_live = live.sum()
    s = tri[:, 0].astype(jnp.int64)
    p = tri[:, 1].astype(jnp.int64)
    o = tri[:, 2].astype(jnp.int64)
    pos_keys = jnp.where(live, (p << 42) | (o << 21) | s, KEY_MAX)
    perm2 = jnp.argsort(pos_keys)
    return tri, sorted_keys, tri[perm2], pos_keys[perm2], n_live


def _squeeze_stream(cands, valid, *, target):
    """Compact a bucketed candidate stream to ``target`` rows (+ overflow)."""
    cols, v, ov = _compact(
        {"s": cands[:, 0], "p": cands[:, 1], "o": cands[:, 2]}, valid, target,
    )
    out = jnp.stack([cols["s"], cols["p"], cols["o"]], axis=1)
    return out, v, ov[None]


class _CountedFn:
    """Callable wrapper counting dispatches through the engine's fn cache.

    Counting wraps the *call*, not the cache fetch — the maintenance host
    helpers fetch a fn once and call it per chunk, and the dispatch floor
    the ROADMAP tracks is calls, not fetches."""

    __slots__ = ("fn", "family", "counter")

    def __init__(self, fn, family: str, counter: DispatchCounter) -> None:
        self.fn = fn
        self.family = family
        self.counter = counter

    def __call__(self, *args):
        self.counter.record(self.family)
        return self.fn(*args)


def _key_family(key) -> str:
    """The fn family of a cache key: its head, unwrapping tagged heads
    like ``("od", n_heads)``."""
    head = key[0] if isinstance(key, tuple) else key
    return head if isinstance(head, str) else head[0]


class JaxEngine:
    """REW materialisation with static capacities; single-device or SPMD.

    Pass ``mesh`` (a 1-D ``jax.sharding.Mesh`` whose axis shards the arena)
    to run distributed; capacities are then per shard.  ``materialise``
    retries with doubled capacities on overflow, so callers normally never
    see :class:`CapacityError`.

    ``materialise_state`` returns a device-resident :class:`EngineState`
    that :meth:`add_facts` / :meth:`delete_facts` maintain on the
    accelerator (epoch-tagged tombstones + owner-routed delta exchange; the
    algorithms live in :mod:`repro.core.incremental_spmd`).
    """

    def __init__(
        self,
        n_resources: int,
        capacity: int = 1 << 12,
        bind_cap: int = 1 << 12,
        out_cap: int = 1 << 12,
        rewrite_cap: int = 1 << 12,
        mesh=None,
        axis: str = "data",
        route_cap: int | None = None,
        seed_chunk: int = 2048,
        delta_out_cap: int | None = None,
        use_kernel: bool = False,
        rederive_mode: str = "targeted",
        fuse_rounds: bool = True,
        delta_window: int = 4096,
    ) -> None:
        self.n_resources = n_resources
        self.capacity = capacity
        self.bind_cap = bind_cap
        self.out_cap = out_cap
        self.rewrite_cap = rewrite_cap
        self.route_cap = route_cap
        # compacted sameAs-pair rows gathered between shards in routed mode;
        # grows independently so a pair burst cannot masquerade as a route
        # overflow (which would retry without ever converging)
        self.pair_cap = min(out_cap, 4096)
        # bounded per-round device-to-host window for the fresh delta's
        # resource masks (process_candidates flags); rounds whose fresh-row
        # count exceeds it fall back to all-True masks — sound but
        # unfiltered, counted in ``stats.delta_mask_fallbacks``.  Tunable
        # mainly so tests can force the fallback path at toy scale.
        self.delta_window = delta_window
        self.seed_chunk = seed_chunk
        # delta/tomb plans of incremental updates emit into much smaller
        # buffers than full-evaluation plans — the candidate stream (and its
        # sorts) then scales with the update's blast radius, not with the
        # base fixpoint's worst round.  The base run itself uses ``out_cap``
        # for every plan (its early deltas are dataset-sized).  The same
        # narrowing applies to the join binding table (``delta_bind``) and
        # the sweep rewrite buffer (``delta_rewrite``): with the persistent
        # index covering membership, these padded widths are what is left
        # of the arena-proportional per-round cost.
        self.delta_out = delta_out_cap or min(out_cap, max(1 << 12, out_cap >> 4))
        # bind holds JOIN INTERMEDIATES, which on rule-heavy programs exceed
        # the delta long before the candidate stream does — its floor is one
        # notch higher so typical updates never pay a growth retry
        self.delta_bind = min(bind_cap, max(1 << 13, bind_cap >> 4))
        self.delta_rewrite = min(rewrite_cap, max(1 << 11, rewrite_cap >> 4))
        self._active_delta_out = out_cap
        self._active_delta_kind = "out"
        self._active_bind = bind_cap
        self._active_bind_kind = "bind"
        self._active_rewrite = rewrite_cap
        self._active_rewrite_kind = "rewrite"
        # an update whose blast radius exceeds a narrow delta buffer retries
        # with the WIDE (base-run, already-compiled) buffers instead of
        # rediscovering the right delta width one doubling-plus-recompile at
        # a time; the named delta cap still doubles once.  The flag is
        # STICKY across operations — a workload whose updates are
        # store-scale (clique-split-heavy deletes on small stores) should
        # not pay a narrow attempt + rollback per op — but every few ops
        # :meth:`_maybe_reset_fallback` probes narrow again, so one
        # anomalous giant update cannot degrade a delta-scale stream
        # permanently.
        self._delta_fallback = False
        # whether the engine is inside a maintenance operation (add/delete)
        # as opposed to a base materialisation; kept in sync by
        # :meth:`_set_update_buffers` and gates merge-targeted requeue
        self._updating = False
        # update_epoch at which fallback mode was (last) entered/probed —
        # the narrow re-probe schedule is keyed off epoch barriers, which
        # advance once per operation whether the rounds run host-looped or
        # as one fused fixpoint (a per-round counter stopped advancing when
        # the round loop moved on device)
        self._fallback_since: int | None = None
        # delete-side rederivation strategy: "targeted" chains the rederive
        # join backward from the overdeleted head instances (the default);
        # "requeue" keeps the historical whole-rule re-evaluation — retained
        # as the differential-testing baseline (tests/test_incremental_spmd)
        if rederive_mode not in ("targeted", "requeue"):
            raise ValueError(f"unknown rederive_mode {rederive_mode!r}")
        self.rederive_mode = rederive_mode
        self.use_kernel = use_kernel
        # fuse the inner maintenance round loop into one compiled
        # lax.while_loop fixpoint per pass (repro.core.fused); False keeps
        # the host-orchestrated per-round loop — the differential baseline
        self.fuse_rounds = fuse_rounds
        self.mesh = mesh
        self.axis = axis if mesh is not None else None
        self.n_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
        self._fns: dict = {}
        # runtime half of the dispatch auditor: every call through the fn
        # cache is recorded by family (+ the maintenance phase, when one is
        # tagged); repro.analysis cross-checks against the static profile
        self.dispatches = DispatchCounter()

    @classmethod
    def from_config(cls, cfg, mesh=None, axis: str = "data", **overrides):
        """Build an engine from a :mod:`repro.configs.sameas_rew` EngineConfig."""
        kw = dict(
            n_resources=cfg.n_resources,
            capacity=cfg.capacity,
            bind_cap=cfg.bind_cap,
            out_cap=cfg.out_cap,
            rewrite_cap=cfg.rewrite_cap,
            route_cap=cfg.route_cap,
            seed_chunk=getattr(cfg, "seed_chunk", 2048),
            delta_out_cap=getattr(cfg, "delta_out_cap", None),
        )
        kw.update(overrides)
        return cls(mesh=mesh, axis=axis, **kw)

    # -- jit wrappers -------------------------------------------------------
    def _wrap(self, fn, in_specs, out_specs):
        if self.mesh is None:
            return jax.jit(fn)
        return jax.jit(
            compat_shard_map(
                fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            )
        )

    def _register_fn(self, key, fn) -> "_CountedFn":
        """Install a compiled fn in the cache under dispatch accounting.

        Every cache fill goes through here (``("padbuf", ...)`` entries are
        device *buffers*, not fns — they bypass this and stay uncounted) so
        each subsequent call records one dispatch under the key's family.
        """
        counted = _CountedFn(fn, _key_family(key), self.dispatches)
        self.dispatches.record_compile(counted.family)
        self._fns[key] = counted
        return counted

    # buffer family of each growable cap attr: cache keys tag every cap
    # value with its family, so eviction after growth is precise even when
    # two different buffers happen to share a width
    _CAP_FAMILY = {
        "bind_cap": "bind", "delta_bind": "bind",
        "out_cap": "out", "delta_out": "out",
        "rewrite_cap": "rewrite", "delta_rewrite": "rewrite",
        "pair_cap": "pair", "route_cap": "route",
    }

    def _get_plan_fn(self, plan_key, plan, head_slots, bind_cap, out_cap):
        if plan_key not in self._fns:
            a = self.axis
            fn = partial(
                eval_plan,
                plan=plan,
                head_var_slots=head_slots,
                bind_cap=bind_cap,
                out_cap=out_cap,
                axis=a,
                use_kernel=self.use_kernel,
            )
            d = P(a) if a else None
            rpl = P() if a else None
            self._register_fn(plan_key, self._wrap(
                fn,
                in_specs=(d, d, d, d, d, d, rpl, rpl, rpl),
                out_specs=(d, d, d, d, d, d),
            ))
        return self._fns[plan_key]

    def _get_squeeze_fn(self, n_rows: int, target: int):
        """Compact a wide bucketed candidate stream down to ``target`` rows.

        Rounds can bucket several plan buffers (rederive even full-width
        ones); their valid rows almost always fit one active-width buffer,
        and squeezing once is far cheaper than dragging the padded width
        through the process step's sorts (which touch the stream ~4x after
        refl expansion).  During updates ``target`` is the narrow
        ``delta_out`` width, so steady-state rounds stream delta-sized
        buffers end to end.
        """
        key = ("squeeze", n_rows, ("out", target))
        if key not in self._fns:
            a = self.axis
            fn = partial(_squeeze_stream, target=target)
            d = P(a) if a else None
            self._register_fn(
                key, self._wrap(fn, in_specs=(d, d), out_specs=(d, d, d))
            )
        return self._fns[key]

    def _get_process_fn(self, n_cand_rows: int):
        key = (
            "process", n_cand_rows, ("rewrite", self._active_rewrite),
            ("route", self.route_cap), ("out", self.out_cap),
            ("pair", self.pair_cap), ("dwin", self.delta_window),
        )
        if key not in self._fns:
            a = self.axis
            fn = partial(
                process_candidates,
                rewrite_cap=self._active_rewrite,
                axis=a,
                n_shards=self.n_shards,
                route_cap=self.route_cap if a is not None else None,
                pair_cap=self.pair_cap,
                use_kernel=self.use_kernel,
                delta_window=self.delta_window,
            )
            d = P(a) if a else None
            rpl = P() if a else None
            flag_specs = {
                "rep_changed": rpl,
                "contradiction": rpl,
                "ov_rewrite": d,
                "ov_store": d,
                "ov_route": d,
                "ov_pair": d,
                "n_new": d,
                "n_pairs": rpl,
                "n_marked": d,
                "n_reflexive": d,
                "delta_rows": d,
                "delta_valid": d,
            }
            self._register_fn(key, self._wrap(
                fn,
                in_specs=(d, d, d, d, rpl, d, d, d, d, rpl),
                out_specs=(d, d, d, d, rpl, d, d, flag_specs),
            ))
        return self._fns[key]

    # -- state lifecycle -----------------------------------------------------
    def _fresh_state(self, program: Program) -> EngineState:
        cap, D = self.capacity, self.n_shards
        return EngineState(
            spo=jnp.zeros(((cap + 1) * D, 3), I32),
            epoch=jnp.full(((cap + 1) * D,), -1, I32),
            marked=jnp.zeros(((cap + 1) * D,), bool),
            tomb=jnp.full(((cap + 1) * D,), -1, I32),
            n_used=jnp.zeros((D,), I32),
            rep=jnp.arange(self.n_resources, dtype=I32),
            # a valid index of the empty store: KEY_MAX padding pointing at
            # each shard's trash row (local index ``cap``)
            sort_perm=jnp.full(((cap + 1) * D,), cap, I32),
            sorted_keys=jnp.full(((cap + 1) * D,), KEY_MAX, jnp.int64),
            program=program,
            base_program=program,
            explicit=np.zeros((0, 3), np.int32),
            r=0,
            stats=MatStats(
                mode="REW-jax" + ("-spmd" if self.mesh is not None else "")
            ),
        )

    def _pad_cands(self, rows: np.ndarray):
        """Pad a host candidate batch to the active candidate stream shape.

        During updates that is the narrow ``delta_out`` width — the whole
        round then streams delta-sized buffers through the process step —
        and during the base run the full ``out_cap``.
        """
        rows = np.asarray(rows, np.int32).reshape(-1, 3)
        rows_global = self._active_delta_out * self.n_shards
        if rows.shape[0] > rows_global:
            raise CapacityError(self._active_delta_kind)
        pad = rows_global - rows.shape[0]
        cands = jnp.asarray(np.pad(rows, ((0, pad), (0, 0))), I32)
        cand_valid = jnp.asarray(np.arange(rows_global) < rows.shape[0])
        return cands, cand_valid

    def _set_update_buffers(self, updating: bool) -> None:
        """Select the output buffer delta/tomb plans emit into.

        During maintenance updates those are the narrow ``delta_out`` /
        ``delta_bind`` / ``delta_rewrite`` buffers; during the base run —
        or an update retrying after a delta-buffer overflow
        (``_delta_fallback``) — the full ``out_cap`` / ``bind_cap`` /
        ``rewrite_cap`` (base-run widths, so their compiled fns are reused
        rather than recompiled per doubling).  The active *kind* names the
        capacity a retry must grow — the buffers can coincide in size, so
        the label cannot be recovered from the value.
        """
        narrow = updating and not self._delta_fallback
        self._updating = updating
        self._active_delta_out = self.delta_out if narrow else self.out_cap
        self._active_delta_kind = "delta_out" if narrow else "out"
        self._active_bind = self.delta_bind if narrow else self.bind_cap
        self._active_bind_kind = "delta_bind" if narrow else "bind"
        self._active_rewrite = self.delta_rewrite if narrow else self.rewrite_cap
        self._active_rewrite_kind = "delta_rewrite" if narrow else "rewrite"

    def _evict_stale_fns(self, grew: set) -> None:
        """Drop compiled fns (and padbuf device buffers) that baked an
        outgrown capacity.  ``grew`` holds ``(family, old_value)`` pairs
        and cache keys tag every cap with its buffer family, so eviction
        is precise: growing ``bind`` no longer evicts every fn that merely
        mentions an *equal* ``out`` width — the collateral recompile storm
        that used to follow a mid-stream growth.  Keys whose widths are
        *derived* from the caps (padbuf buffers, process/squeeze stream
        widths) carry bare ints; those are matched by value, since an
        outgrown width can no longer be produced and would otherwise
        retain its XLA executable / device buffers for the engine's (a
        standing service's) lifetime — a coincidental match there merely
        costs one recompile."""
        old_values = {v for _, v in grew}

        def hit(x, by_value=False):
            if isinstance(x, tuple):
                if len(x) == 2 and isinstance(x[0], str) and x in grew:
                    return True
                return any(hit(y, by_value) for y in x)
            return by_value and isinstance(x, int) and x in old_values

        def stale(key):
            by_value = (
                isinstance(key, tuple)
                and key
                and key[0] in ("padbuf", "process", "squeeze", "fforward")
            )
            return hit(key, by_value)

        for key in [k for k in self._fns if stale(k)]:
            del self._fns[key]

    def _grow_for(self, kind: str) -> None:
        """Double exactly the capacity a :class:`CapacityError` names.

        Growing only the exhausted buffer keeps padded join/sort costs
        proportional to the workload — a bind-table overflow must not
        quadruple the arena sort.  Every tunable cap is part of the compiled
        fn cache keys (and jit itself re-traces on array-shape changes), so
        correctness needs no invalidation; stale-cap entries are still
        evicted so their executables are reclaimed.
        """
        grew: set = set()

        def double(attr: str, factor: int = 2) -> None:
            # the arena capacity is not part of any fn cache key (jit
            # re-traces on the new array shapes), so it never marks stale
            if attr != "capacity":
                grew.add((self._CAP_FAMILY[attr], getattr(self, attr)))
            setattr(self, attr, getattr(self, attr) * factor)

        # each wide-cap growth mid-update restarts the operation and
        # recompiles every fn keyed on the outgrown width; once an update
        # is already in its fallback retry, grow x4 to halve those restarts
        wide_factor = 4 if self._delta_fallback else 2

        if kind == "store":
            double("capacity")
        elif kind == "bind":
            double("bind_cap", wide_factor)
        elif kind in ("out", "out_cap"):
            double("out_cap", wide_factor)
        elif kind == "rewrite":
            double("rewrite_cap", wide_factor)
        elif kind in ("delta_out", "delta_bind", "delta_rewrite"):
            # a delta buffer overflowed: double it for FUTURE updates, but
            # retry the current one against the wide (base-run, compiled)
            # buffers — iterative width discovery would recompile every
            # delta-width fn per doubling.  Clamped at the wide cap: on a
            # persistently store-scale workload the periodic narrow probe
            # must not keep doubling (and recompiling) past the width the
            # wide buffers already provide — all caps are powers of two,
            # so doubling from below the wide cap never overshoots it.
            wide = {"delta_out": "out_cap", "delta_bind": "bind_cap",
                    "delta_rewrite": "rewrite_cap"}[kind]
            if getattr(self, kind) < getattr(self, wide):
                double(kind)
            self._delta_fallback = True
            self._fallback_since = None  # restart the narrow-probe clock
        elif kind == "pair":
            double("pair_cap")
        elif kind == "route" and self.route_cap is not None:
            double("route_cap")
        else:  # unknown kind: grow everything (defensive)
            for attr in ("capacity", "bind_cap", "delta_bind", "out_cap",
                         "delta_out", "rewrite_cap", "delta_rewrite",
                         "pair_cap"):
                double(attr)
            if self.route_cap is not None:
                double("route_cap")
        # keep the active delta buffer (and its retry label) in sync with
        # whichever capacity the running operation is emitting into
        self._set_update_buffers(self._active_delta_kind == "delta_out")
        if grew:
            self._evict_stale_fns(grew)

    def _bucket_cands(self, bufs):
        """Concatenate plan output buffers, padding each width group with
        empty buffers to a power-of-two count — process fns then compile for
        O(log #plans) distinct candidate widths instead of one per plan
        subset (the delta-mask filter makes the subset vary round to round,
        and delta plans emit narrower buffers than full plans)."""
        groups: dict[int, list] = {}
        for b in bufs:
            groups.setdefault(int(b[0].shape[0]), []).append(b)
        heads, valids = [], []
        for rows, bs in sorted(groups.items()):
            total = 1
            while total < len(bs):
                total *= 2
            key = ("padbuf", rows)
            if key not in self._fns:
                self._fns[key] = (
                    jnp.zeros((rows, 3), I32),
                    jnp.zeros((rows,), bool),
                )
            pad_h, pad_v = self._fns[key]
            heads += [b[0] for b in bs] + [pad_h] * (total - len(bs))
            valids += [b[1] for b in bs] + [pad_v] * (total - len(bs))
        return jnp.concatenate(heads, axis=0), jnp.concatenate(valids, axis=0)

    def _grow_state_arena(self, state: EngineState, old_cap: int) -> None:
        """Re-layout the sharded arena columns after ``capacity`` doubled.

        Each shard's block grows from ``old_cap + 1`` to ``capacity + 1``
        rows; the old trash slot becomes an ordinary free row (dead, epoch
        -1) that insertion reuses once ``n_used`` reaches it.
        """
        D, new_cap = self.n_shards, self.capacity

        def regrow(x, fill):
            h = np.asarray(x)
            h = h.reshape(D, old_cap + 1, *h.shape[1:])
            pad = [(0, 0)] * h.ndim
            pad[1] = (0, new_cap - old_cap)
            h = np.pad(h, pad, constant_values=fill)
            return jnp.asarray(h.reshape(D * (new_cap + 1), *h.shape[2:]))

        state.spo = regrow(state.spo, 0)
        state.epoch = regrow(state.epoch, -1)
        state.marked = regrow(state.marked, False)
        state.tomb = regrow(state.tomb, -1)
        # the sorted index keys survive the re-layout unchanged but the
        # arrays are the wrong shape now; rebuild lazily (the one full
        # argsort this mutation epoch) at the next operation's start
        state.index_dirty = True

    @staticmethod
    def _snapshot(state: EngineState) -> dict:
        import copy

        snap = {f: getattr(state, f) for f in (
            "spo", "epoch", "marked", "tomb", "n_used", "rep",
            "sort_perm", "sorted_keys", "index_dirty",
            "program", "explicit", "r", "update_epoch",
        )}
        snap["stats"] = copy.copy(state.stats)
        return snap

    @staticmethod
    def _restore(state: EngineState, snap: dict) -> None:
        for f, v in snap.items():
            setattr(state, f, v)

    def _maybe_reset_fallback(self, state: EngineState) -> None:
        """Sticky wide-buffer fallback with a periodic narrow probe.

        Once ``state.update_epoch`` has advanced 4 epoch barriers past the
        epoch at which fallback was entered (or last re-asserted by a delta
        overflow), the next operation tries the narrow delta buffers again
        — one rollback if the workload is still store-scale, a return to
        delta-scale costs if load has dropped.  The schedule is keyed off
        epoch barriers (one per committed operation) rather than any round
        count: the fused fixpoint advances rounds on device, so a per-round
        or per-call counter would tick at a rate that depends on how the
        rounds are orchestrated, not on how many operations ran.
        """
        if not self._delta_fallback:
            self._fallback_since = None
            return
        if self._fallback_since is None:
            self._fallback_since = state.update_epoch
        elif state.update_epoch - self._fallback_since >= 4:
            self._delta_fallback = False
            self._fallback_since = None

    def _presize_delta(self, n_rows: int) -> None:
        """Pre-size the delta buffers for a KNOWN cardinality — the admitted
        batch or the finalised overdelete delta — so mid-stream width
        discovery (overflow -> rollback -> growth -> recompile, repeated)
        never fires for a width the driver can predict up front.  The
        narrow delta caps grow to cover ``n_rows`` (clamped at the wide
        caps, matching the overflow path's clamp); a cardinality exceeding
        even the wide caps grows those too — *without* a restart, since
        this runs at a phase boundary with no buffers in flight.

        ``n_rows`` is a GLOBAL cardinality while every cap is per shard
        (``_pad_cands``: global stream width = cap x n_shards), so the
        target width divides by the shard count — a skewed row
        distribution is the overflow retry's job, exactly as for any other
        per-shard buffer.

        An EMPTY admitted batch (a no-op epoch) still selects buffers: the
        cardinality clamps to 1 so the pow2 target is the minimum delta
        width, never a degenerate 0-row presize that the next phase would
        have to repair with a width-discovery restart booked against
        ``wide_growth_restarts`` on an idle epoch.
        """
        n_rows = max(int(n_rows), 1)
        need = _pow2(-(-n_rows // self.n_shards))
        grew: set = set()
        for attr, wide in (
            ("delta_out", "out_cap"),
            ("delta_bind", "bind_cap"),
            ("delta_rewrite", "rewrite_cap"),
        ):
            if getattr(self, wide) < need:
                grew.add((self._CAP_FAMILY[wide], getattr(self, wide)))
                setattr(self, wide, need)
            target = min(need, getattr(self, wide))
            if getattr(self, attr) < target:
                grew.add((self._CAP_FAMILY[attr], getattr(self, attr)))
                setattr(self, attr, target)
        self._set_update_buffers(True)
        if grew:
            self._evict_stale_fns(grew)

    def _ensure_index(self, state: EngineState) -> None:
        """(Re)build the persistent sorted index if it is stale.

        The ONLY full argsort of the arena, paid at most once per mutation
        epoch — after a capacity re-layout, or to adopt a hand-built state
        — never inside the round loop (``stats.index_rebuilds`` counts the
        sorts so tests can pin that budget).  Must run inside the engine's
        x64 scope.
        """
        if not state.index_dirty:
            return
        key = ("rebuild_index",)
        if key not in self._fns:
            a = self.axis
            d = P(a) if a else None
            self._register_fn(
                key,
                self._wrap(_rebuild_index, in_specs=(d, d, d), out_specs=(d, d)),
            )
        state.sort_perm, state.sorted_keys = self._fns[key](
            state.spo, state.epoch, state.marked
        )
        state.index_dirty = False
        state.stats.index_rebuilds += 1

    def _refresh_stats(self, state: EngineState) -> None:
        stats = state.stats
        stats.triples_total = int(np.asarray(state.n_used).sum())
        stats.merged_resources = int(
            (compress_np(np.asarray(state.rep)) != np.arange(state.n_res)).sum()
        )
        stats.triples_explicit = state.explicit.shape[0]

    def state_triples(self, state: EngineState) -> np.ndarray:
        """The current normal-form store as a host (n, 3) array."""
        epoch = np.asarray(state.epoch)
        marked = np.asarray(state.marked)
        live = (epoch >= 0) & ~marked
        state.stats.triples_unmarked = int(live.sum())
        return np.asarray(state.spo)[live]

    def state_rep(self, state: EngineState) -> np.ndarray:
        return compress_np(np.asarray(state.rep))

    def snapshot_arrays(
        self, spo, epoch, marked, rep, at_epoch: int,
        sort_perm=None, sorted_keys=None, index_dirty: bool = True,
    ) -> StoreSnapshot:
        """Build a :class:`StoreSnapshot` from raw barrier-consistent arrays.

        The arrays must describe an epoch barrier (an operation fixpoint) —
        either a live :class:`EngineState` between updates, or the rollback
        snapshot captured before an in-flight update started (the serving
        scheduler's lazy-publication path).  When the persistent sorted
        index is supplied (and clean), the live rows are extracted through
        it — one gather instead of a full-arena boolean scan, and the
        published triples come out packed-key-sorted per shard block.
        """
        if sorted_keys is not None and not index_dirty:
            keys = np.asarray(sorted_keys).reshape(self.n_shards, -1)
            perm = np.asarray(sort_perm).reshape(self.n_shards, -1)
            spo_h = np.asarray(spo).reshape(self.n_shards, keys.shape[1], 3)
            triples = np.concatenate(
                [spo_h[s][perm[s][keys[s] < KEY_MAX]] for s in range(self.n_shards)],
                axis=0,
            )
        else:
            live = (np.asarray(epoch) >= 0) & ~np.asarray(marked)
            triples = np.asarray(spo)[live]
        triples.setflags(write=False)  # shared by every reader at this epoch
        return StoreSnapshot(
            epoch=at_epoch,
            triples=triples,
            rho=FrozenRho(np.asarray(rep)),
        )

    def read_snapshot(self, state: EngineState) -> StoreSnapshot:
        """Epoch-versioned read snapshot: host triples copy + frozen rho.

        Only valid at an epoch barrier (no update in flight on ``state``) —
        mid-operation the arena holds tombstoned-but-not-yet-rederived rows
        that no reader may observe.  :meth:`add_facts`/:meth:`delete_facts`
        bump ``state.update_epoch`` exactly when the barrier is reached, so
        snapshots taken between public API calls are always consistent.
        Serving epochs reuse the persistent index for free: live rows come
        out through one ``sort_perm`` gather.
        """
        snap = self.snapshot_arrays(
            state.spo, state.epoch, state.marked, state.rep, state.update_epoch,
            sort_perm=state.sort_perm, sorted_keys=state.sorted_keys,
            index_dirty=state.index_dirty,
        )
        state.stats.triples_unmarked = int(snap.triples.shape[0])
        return snap

    def publish_snapshot(
        self, state: EngineState, prev: StoreSnapshot | None = None,
    ) -> StoreSnapshot:
        """Device-resident epoch snapshot — the serving publication step.

        Like :meth:`read_snapshot` this is only valid at an epoch barrier,
        but instead of copying the live rows to host it keeps them on the
        accelerator in the two sorted orders the batched query executor
        range-probes (:func:`_publish_snapshot`); the host ``triples`` copy
        is materialised lazily only if a host-path reader asks for it.
        ``prev`` (the previously published snapshot) enables the
        incremental :meth:`~repro.core.uf.FrozenRho.refreshed` rho refresh:
        epochs that touched no clique reuse the entire expansion table.

        Dispatches are tagged under the ``"publish"`` phase (an index
        rebuild may ride along when the arena was re-laid-out this epoch).
        Falls back to the host path under SPMD: per-shard sorted blocks
        are not a globally sorted view, and the serving store is a
        single-controller tier.
        """
        if self.n_shards != 1:
            snap = self.read_snapshot(state)
            if prev is not None:
                snap.rho = prev.rho.refreshed(np.asarray(state.rep))
            return snap
        prev_phase = self.dispatches.phase
        self.dispatches.phase = "publish"
        try:
            with enable_x64():
                self._ensure_index(state)
                key = ("snapshot", int(state.spo.shape[0]))
                if key not in self._fns:
                    self._register_fn(key, jax.jit(_publish_snapshot))
                tri, keys, tri_pos, keys_pos, n_live = self._fns[key](
                    state.spo, state.sort_perm, state.sorted_keys
                )
        finally:
            self.dispatches.phase = prev_phase
        rep_host = np.asarray(state.rep)
        rho = prev.rho.refreshed(rep_host) if prev is not None \
            else FrozenRho(rep_host)
        n_live = int(n_live)
        state.stats.triples_unmarked = n_live
        return StoreSnapshot(
            state.update_epoch, rho,
            device=(tri, keys, tri_pos, keys_pos, n_live),
        )

    def _recover_capacity(
        self, state: EngineState, snap: dict, err: CapacityError
    ) -> None:
        """Roll back to ``snap``, grow exactly the exhausted capacity, and
        re-layout the sharded arena if the store itself grew — the shared
        retry step of :meth:`_apply_update` and the serving scheduler
        (:mod:`repro.serve.triple_store`)."""
        # dispatches issued by the rollback/grow/restart machinery must not
        # inherit whatever phase tag was live (or stale) when the overflow
        # fired — attribute them to a distinct "retry" phase the static
        # dispatch profile admits; the restarted generator re-tags its own
        # phases from the top
        self.dispatches.phase = "retry"
        self._restore(state, snap)
        old_cap = self.capacity
        kind = str(err)
        self._grow_for(kind)
        if self.capacity != old_cap:
            self._grow_state_arena(state, old_cap)
        # restart bookkeeping (BENCH_incremental records these per profile):
        # every retry rolls the operation back; growing a WIDE cap
        # additionally recompiles every fn keyed on the outgrown width —
        # the "wide-growth discovery" cost _presize_delta exists to avoid
        state.stats.capacity_retries += 1
        if kind in ("bind", "out", "out_cap", "rewrite"):
            state.stats.wide_growth_restarts += 1

    def _barrier(self, state: EngineState) -> None:
        """The epoch barrier: an update operation's fixpoint is complete.
        No-op updates cross it too — their fixpoint is the unchanged store,
        and readers' epochs must stay monotone and attributable."""
        state.update_epoch += 1
        self._refresh_stats(state)

    def _rewrite_program(self, state: EngineState, stats):
        """Rewrite the program under the compressed current rho and classify
        each changed rule for re-evaluation.

        The ONE booking site for ``rule_rewrites``/``rules_requeued`` —
        both the host round loop and the fused rewrite-due exit go through
        here, so a single rho change can never be double-booked no matter
        which loop detected it (the fused exit round is re-run by the host,
        which used to hold its own copy of this block).

        Returns ``(merge_q, full_q)``: ``merge_q`` is ``[(rule_idx,
        anchor_atom), ...]`` for merge-targeted evaluation
        (:meth:`_eval_rule_merge`), ``full_q`` the rules that keep the
        whole-rule full-plan requeue — every changed rule when
        ``rederive_mode="requeue"`` (the differential baseline), else only
        the variable-free-anchor corner cases (``remerge_full_fallback``).
        """
        rep_host = compress_np(np.asarray(state.rep))
        p_old = state.program
        p_new, changed_idx = p_old.rewrite(rep_host)
        merge_q: list[tuple[int, int]] = []
        full_q: list[int] = []
        if changed_idx:
            stats.rule_rewrites += 1
            stats.rules_requeued += len(changed_idx)
            # targeting applies to MAINTENANCE operations (like the delete
            # side's rederive): the base fixpoint keeps the whole-rule
            # requeue so its derivation/application counters stay exactly
            # the paper's Table 2 semantics (parity with the numpy oracle)
            targeted = self._updating and self.rederive_mode == "targeted"
            for k in changed_idx:
                if not targeted:
                    full_q.append(k)
                    continue
                how, anchor = classify_remerge(p_old.rules[k], p_new.rules[k])
                if how == "anchor":
                    merge_q.append((k, anchor))
                elif how == "full":
                    full_q.append(k)
                    stats.remerge_full_fallback += 1
                # "skip": head-only change — the sweep re-normalises the
                # stored head instances, no evaluation needed
        state.program = p_new
        return merge_q, full_q

    def _eval_rule_merge(
        self, state: EngineState, r, rule: Rule, k: int, anchor: int, stats
    ):
        """Merge-targeted evaluation of one rewritten rule — the
        forward-side analogue of the delete side's head-bound rederivation
        (:meth:`_eval_rule_rederive`): one plan anchored at the changed
        body atom against the pre-merge store, remaining atoms chained
        through the live store via the persistent index.  Runs at the
        narrow active delta buffers — the join width scales with the
        merged cliques' footprint, never the arena.
        """
        atom_consts = np.zeros((len(rule.body), 3), np.int32)
        for j, atom in enumerate(rule.body):
            for pos, t in enumerate(atom):
                atom_consts[j, pos] = 0 if is_var(t) else t
        head_consts = np.asarray(
            [0 if is_var(t) else t for t in rule.head], np.int32
        )
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        plan_t = tuple(build_merge_plan(rule, anchor))
        bind_cap, out_cap = self._active_bind, self._active_delta_out
        fn = self._get_plan_fn(
            ("mplan", k, anchor, plan_t, head_slots,
             ("bind", bind_cap), ("out", out_cap)),
            plan_t, head_slots, bind_cap, out_cap,
        )
        heads, valid, n_d, n_a, ov_bind, ov_out = fn(
            state.spo, state.epoch, state.marked, state.tomb,
            state.sorted_keys, state.sort_perm,
            jnp.asarray(r, I32),
            jnp.asarray(atom_consts), jnp.asarray(head_consts),
        )
        if bool(np.asarray(ov_bind).any()):
            raise CapacityError(self._active_bind_kind)
        if bool(np.asarray(ov_out).any()):
            raise CapacityError(self._active_delta_kind)
        stats.derivations += int(np.asarray(n_d).sum())
        stats.rule_applications += int(np.asarray(n_a).sum())
        stats.remerge_targeted += 1
        return [(heads, valid)]

    # -- driver --------------------------------------------------------------
    def _forward(
        self,
        state: EngineState,
        cands,
        cand_valid,
        requeued: list[int],
        max_rounds: int,
    ) -> None:
        """The shared bulk-synchronous round loop, resuming from ``state``.

        Used by the base fixpoint (seeded with the explicit facts), additions
        (seeded with the delta batch) and the delete path's rederive/forward
        pass (seeded with the rederivation candidates + a requeue of every
        rule whose head can restore an overdeleted fact).  ``state.r`` keeps
        increasing across invocations so the epoch discipline is preserved:
        the first round here inserts at a fresh epoch, and the next round's
        delta plans match exactly those rows.
        """
        stats = state.stats
        requeued = list(requeued)
        rounds_here = 0
        first = True
        have_cands = True
        while first or have_cands or requeued:
            first = False
            # fused fixpoint: while the stream sits at the active delta
            # width and no full-plan requeue is pending, run the whole
            # inner loop as ONE compiled lax.while_loop.  Requeued rules
            # and post-requeue WIDE streams (squeezed to out_cap) take the
            # host-orchestrated round below — delta plans narrow the
            # stream back within one round, and the fused loop resumes.
            if (
                self.fuse_rounds
                and not requeued
                and int(cands.shape[0]) == self._active_delta_out * self.n_shards
            ):
                if rounds_here >= max_rounds:
                    raise RuntimeError("did not converge")
                iters, cands, cand_valid, have_cands = self._fused_forward(
                    state, cands, cand_valid, max_rounds - rounds_here
                )
                rounds_here += iters
                continue
            state.r += 1
            r = state.r
            stats.rounds += 1
            rounds_here += 1
            if rounds_here > max_rounds:
                raise RuntimeError("did not converge")
            proc = self._get_process_fn(int(cands.shape[0]))
            spo, epoch, marked, n_used, rep_new, sort_perm, sorted_keys, flags = proc(
                state.spo, state.epoch, state.marked, state.n_used, state.rep,
                state.sort_perm, state.sorted_keys,
                cands, cand_valid, jnp.asarray(r, I32),
            )
            state.spo, state.epoch, state.marked, state.n_used = (
                spo, epoch, marked, n_used,
            )
            state.sort_perm, state.sorted_keys = sort_perm, sorted_keys
            for kind in ("store", "rewrite", "route", "pair"):
                if bool(np.asarray(flags["ov_" + kind]).any()):
                    raise CapacityError(
                        self._active_rewrite_kind if kind == "rewrite" else kind
                    )
            if bool(np.asarray(flags["contradiction"]).reshape(-1)[0]):
                from .materialise import Contradiction

                raise Contradiction("owl:differentFrom violation")
            stats.sameas_pairs += int(np.asarray(flags["n_pairs"]).reshape(-1)[0])
            n_refl = int(np.asarray(flags["n_reflexive"]).sum())
            stats.reflexive_added += n_refl
            stats.derivations += n_refl

            rep_changed = bool(np.asarray(flags["rep_changed"]).reshape(-1)[0])
            state.rep = rep_new
            merge_q: list[tuple[int, int]] = []
            if rep_changed:
                mq, full_q = self._rewrite_program(state, stats)
                merge_q.extend(mq)
                requeued.extend(full_q)

            # evaluate plans for the new delta, skipping plans whose delta
            # atom is incompatible with the fresh rows' resource masks
            bufs = []
            had_full = False
            n_new = int(np.asarray(flags["n_new"]).sum())
            if n_new > 0:
                # per-position resource masks of the fresh delta, derived on
                # the host from the compacted delta rows (all shards' rows
                # arrive concatenated, so this is the global delta).  The
                # device truncates the window per shard; if the fresh rows
                # did not all fit, fall back to all-True masks — a superset,
                # so plan skipping stays sound
                d_rows = np.asarray(flags["delta_rows"])
                d_rows = d_rows[np.asarray(flags["delta_valid"])]
                if d_rows.shape[0] < n_new:
                    stats.delta_mask_fallbacks += 1
                    delta_masks = np.ones((3, state.n_res), dtype=bool)
                else:
                    delta_masks = np.zeros((3, state.n_res), dtype=bool)
                    for pos in range(3):
                        delta_masks[pos][d_rows[:, pos]] = True
                for k, rule in enumerate(state.program.rules):
                    bufs += self._eval_rule(
                        state, r + 1, rule, k, "delta", stats,
                        delta_masks=delta_masks,
                    )
            for k, anchor in merge_q:
                bufs += self._eval_rule_merge(
                    state, r + 1, state.program.rules[k], k, anchor, stats
                )
            for k in sorted(set(requeued)):
                bufs += self._eval_rule(
                    state, r + 1, state.program.rules[k], k, "full", stats
                )
                had_full = True
            requeued = []
            if bufs:
                cands, cand_valid = self._bucket_cands(bufs)
                # rounds that evaluated requeued FULL plans can emit
                # store-sized candidate sets — squeeze those to the wide
                # out_cap (whose process fn the base run compiled) instead
                # of forcing the narrow delta width into a growth retry
                target = self.out_cap if had_full else self._active_delta_out
                kind = "out" if had_full else self._active_delta_kind
                rows_global = target * self.n_shards
                if int(cands.shape[0]) > rows_global:
                    sq = self._get_squeeze_fn(int(cands.shape[0]), target)
                    cands, cand_valid, sq_ov = sq(cands, cand_valid)
                    if bool(np.asarray(sq_ov).any()):
                        raise CapacityError(kind)
                have_cands = bool(cand_valid.any())
            else:
                have_cands = False

    def _get_fused_forward_fn(self, n_cand_rows: int, plans_sig: tuple):
        key = (
            "fforward", n_cand_rows, plans_sig,
            ("bind", self._active_bind), ("out", self._active_delta_out),
            ("rewrite", self._active_rewrite), ("route", self.route_cap),
            ("pair", self.pair_cap),
        )
        if key not in self._fns:
            from .fused import fused_forward_rounds

            a = self.axis
            fn = partial(
                fused_forward_rounds,
                plans=plans_sig,
                rewrite_cap=self._active_rewrite,
                bind_cap=self._active_bind,
                plan_out_cap=self._active_delta_out,
                pair_cap=self.pair_cap,
                route_cap=self.route_cap if a is not None else None,
                axis=a,
                n_shards=self.n_shards,
                use_kernel=self.use_kernel,
            )
            d = P(a) if a else None
            rpl = P() if a else None
            flag_specs = {
                "iters": rpl, "have_cands": rpl, "n_new": rpl,
                "n_pairs": rpl,
                "n_reflexive": d, "n_deriv": d, "n_appl": d,
                "ov_store": rpl, "ov_rewrite": rpl, "ov_route": rpl,
                "ov_pair": rpl, "ov_bind": rpl, "ov_out": rpl,
                "ov_squeeze": rpl,
                "contradiction": rpl, "consts_changed": rpl,
            }
            self._register_fn(key, self._wrap(
                fn,
                in_specs=(
                    d, d, d, d, d, rpl, d, d, d, d,
                    rpl, rpl, rpl, rpl, rpl, rpl,
                ),
                out_specs=(d, d, d, d, rpl, d, d, d, d, flag_specs),
            ))
        return self._fns[key]

    def _fused_forward(self, state: EngineState, cands, cand_valid,
                       rounds_left: int):
        """Run forward rounds as one fused on-device fixpoint.

        Returns ``(iters, cands, cand_valid, have_cands)``.  Healthy
        convergence returns an empty stream; a rho-reaches-a-rule-constant
        exit rewrites the program on the host, re-evaluates the exit
        round's plans with the new constants (the device nullified its own
        evaluation of that round) and hands the resulting stream back to
        the driver loop.  Capacity overflow and contradiction raise exactly
        what the per-round host loop would have raised — the snapshot
        rollback upstream makes the committed post-overflow state moot.
        """
        from .fused import forward_plan_signature, program_tables

        stats = state.stats
        plans_sig = forward_plan_signature(state.program)
        fn = self._get_fused_forward_fn(int(cands.shape[0]), plans_sig)
        ac, hc, cv, cvd = program_tables(state.program)
        (spo, epoch, marked, n_used, rep, sort_perm, sorted_keys,
         cands, cand_valid, fl) = fn(
            state.spo, state.epoch, state.marked, state.tomb, state.n_used,
            state.rep, state.sort_perm, state.sorted_keys, cands, cand_valid,
            jnp.asarray(state.r, I32), jnp.asarray(rounds_left, I32),
            ac, hc, cv, cvd,
        )
        state.spo, state.epoch, state.marked, state.n_used = (
            spo, epoch, marked, n_used,
        )
        state.sort_perm, state.sorted_keys = sort_perm, sorted_keys
        state.rep = rep

        def flag(name: str) -> bool:
            return bool(np.asarray(fl[name]).reshape(-1)[0])

        iters = int(np.asarray(fl["iters"]).reshape(-1)[0])
        state.r += iters
        stats.rounds += iters
        stats.sameas_pairs += int(np.asarray(fl["n_pairs"]).reshape(-1)[0])
        n_refl = int(np.asarray(fl["n_reflexive"]).sum())
        stats.reflexive_added += n_refl
        stats.derivations += n_refl + int(np.asarray(fl["n_deriv"]).sum())
        stats.rule_applications += int(np.asarray(fl["n_appl"]).sum())

        for kind in ("store", "rewrite", "route", "pair"):
            if flag("ov_" + kind):
                raise CapacityError(
                    self._active_rewrite_kind if kind == "rewrite" else kind
                )
        if flag("contradiction"):
            from .materialise import Contradiction

            raise Contradiction("owl:differentFrom violation")
        if flag("ov_bind"):
            raise CapacityError(self._active_bind_kind)
        if flag("ov_out") or flag("ov_squeeze"):
            raise CapacityError(self._active_delta_kind)

        if flag("consts_changed"):
            merge_q, full_q = self._rewrite_program(state, stats)
            r = state.r
            bufs = []
            had_full = False
            if int(np.asarray(fl["n_new"]).reshape(-1)[0]) > 0:
                # the exit round's fresh delta was committed on device but
                # its window never crossed to the host — evaluate every
                # delta plan (a sound superset of the mask-filtered set;
                # impossible plans match zero rows and count nothing)
                for k, rule in enumerate(state.program.rules):
                    bufs += self._eval_rule(
                        state, r + 1, rule, k, "delta", stats,
                        delta_masks=None,
                    )
            for k, anchor in merge_q:
                bufs += self._eval_rule_merge(
                    state, r + 1, state.program.rules[k], k, anchor, stats
                )
            for k in sorted(set(full_q)):
                bufs += self._eval_rule(
                    state, r + 1, state.program.rules[k], k, "full", stats
                )
                had_full = True
            if bufs:
                cands, cand_valid = self._bucket_cands(bufs)
                target = self.out_cap if had_full else self._active_delta_out
                kind = "out" if had_full else self._active_delta_kind
                rows_global = target * self.n_shards
                if int(cands.shape[0]) > rows_global:
                    sq = self._get_squeeze_fn(int(cands.shape[0]), target)
                    cands, cand_valid, sq_ov = sq(cands, cand_valid)
                    if bool(np.asarray(sq_ov).any()):
                        raise CapacityError(kind)
                return iters, cands, cand_valid, bool(cand_valid.any())
            return iters, cands, cand_valid, False

        if flag("have_cands"):
            # round budget exhausted with candidates still flowing
            raise RuntimeError("did not converge")
        return iters, cands, cand_valid, False

    @staticmethod
    def _atom_may_match(atom, masks: np.ndarray) -> bool:
        """False iff a constant position of ``atom`` misses the delta masks
        (so the plan's delta atom cannot bind any fresh/frontier row).  A
        per-position relaxation of the numpy engine's ``_const_filter`` — a
        superset of its keep-set, hence sound to skip on False."""
        for pos, t in enumerate(atom):
            if not is_var(t) and not masks[pos][t]:
                return False
        return True

    def _eval_rule(
        self, state: EngineState, r, rule: Rule, k: int, mode: str, stats,
        delta_masks: np.ndarray | None = None,
    ):
        """Evaluate one rule's plans; ``mode`` in {"delta", "full", "tomb"}.

        "tomb" evaluates the overdelete variants (Delta = last tombstone
        wave, everything else = pre-deletion store) with ``r`` = the wave
        number; stats are not counted for those (mirroring the host path,
        which discards overdelete derivation counts).  ``delta_masks``
        (3, n_res) skips delta/tomb plans whose delta atom cannot match the
        current delta — skipped plans would contribute nothing (and count
        nothing: their delta atom matches zero rows).
        """
        atom_consts = np.zeros((len(rule.body), 3), np.int32)
        for j, atom in enumerate(rule.body):
            for pos, t in enumerate(atom):
                atom_consts[j, pos] = 0 if is_var(t) else t
        head_consts = np.asarray([0 if is_var(t) else t for t in rule.head], np.int32)
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        plans = build_plans(rule, full=(mode == "full"), tombstone=(mode == "tomb"))
        # full-evaluation plans keep the wide buffers (their bindings can be
        # store-sized); delta/tomb plans use whichever narrow buffers the
        # running operation activated — joins then sort/pad with the delta
        full_plan = mode == "full"
        out_cap = self.out_cap if full_plan else self._active_delta_out
        bind_cap = self.bind_cap if full_plan else self._active_bind
        out = []
        for i, plan in enumerate(plans):
            if (
                delta_masks is not None
                and mode in ("delta", "tomb")
                and not self._atom_may_match(rule.body[i], delta_masks)
            ):
                continue
            plan_t = tuple(plan)
            fn = self._get_plan_fn(
                ("plan", k, i, mode, plan_t, head_slots,
                 ("bind", bind_cap), ("out", out_cap)),
                plan_t, head_slots, bind_cap, out_cap,
            )
            heads, valid, n_d, n_a, ov_bind, ov_out = fn(
                state.spo, state.epoch, state.marked, state.tomb,
                state.sorted_keys, state.sort_perm,
                jnp.asarray(r, I32),
                jnp.asarray(atom_consts), jnp.asarray(head_consts),
            )
            if bool(np.asarray(ov_bind).any()):
                raise CapacityError(
                    "bind" if full_plan else self._active_bind_kind
                )
            if bool(np.asarray(ov_out).any()):
                # full plans always emit into out_cap; delta/tomb plans into
                # whichever buffer is active (the kind label, not a value
                # comparison — the two caps may coincide in size)
                raise CapacityError(
                    "out" if mode == "full" else self._active_delta_kind
                )
            if stats is not None:
                stats.derivations += int(np.asarray(n_d).sum())
                stats.rule_applications += int(np.asarray(n_a).sum())
                if full_plan:
                    stats.full_plan_evals += 1
            out.append((heads, valid))
        return out

    def _get_rederive_fn(self, key, plan, head_slots, seed_vars, bind_cap, out_cap):
        if key not in self._fns:
            a = self.axis
            fn = partial(
                eval_plan_rederive,
                plan=plan,
                head_var_slots=head_slots,
                seed_vars=seed_vars,
                bind_cap=bind_cap,
                out_cap=out_cap,
                axis=a,
                use_kernel=self.use_kernel,
            )
            d = P(a) if a else None
            rpl = P() if a else None
            self._register_fn(key, self._wrap(
                fn,
                in_specs=(d, d, d, d, d, d, rpl, rpl, rpl, rpl),
                out_specs=(d, d, d, d, d),
            ))
        return self._fns[key]

    def _eval_rule_rederive(self, state: EngineState, k: int, rule: Rule, seeds):
        """Backward-chained, head-bound evaluation of one rule — the
        delete-side targeted rederivation step.

        ``seeds`` is the (m, n_head_vars) host table of head-variable
        bindings extracted from the overdeleted instances
        (``incremental_spmd._head_bindings``, column order =
        :func:`build_rederive_plan`'s ``head_vars``).  The body joins run
        against the surviving live store through the persistent sorted
        index, so join width scales with the overdelete delta — never the
        arena.  Returns the restored instances as host (n, 3) rows.
        """
        plan, seed_vars = build_rederive_plan(rule)
        atom_consts = np.zeros((len(rule.body), 3), np.int32)
        for j, atom in enumerate(rule.body):
            for pos, t in enumerate(atom):
                atom_consts[j, pos] = 0 if is_var(t) else t
        head_consts = np.asarray(
            [0 if is_var(t) else t for t in rule.head], np.int32
        )
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        seeds = np.asarray(seeds, np.int32)
        if seeds.ndim != 2 or seeds.shape[1] != len(seed_vars):
            raise ValueError(
                f"seed table shape {seeds.shape} does not match the head's "
                f"variable order {seed_vars} (see build_rederive_plan)"
            )
        cap = max(64, _pow2(seeds.shape[0]))
        pad = cap - seeds.shape[0]
        seeds_j = jnp.asarray(np.pad(seeds, ((0, pad), (0, 0))), I32)
        valid_j = jnp.asarray(np.arange(cap) < seeds.shape[0])
        bind_cap, out_cap = self._active_bind, self._active_delta_out
        stats = state.stats
        stats.rederive_seed_rows += int(seeds.shape[0])
        stats.rederive_join_width = max(stats.rederive_join_width, cap)
        fn = self._get_rederive_fn(
            ("rplan", k, tuple(plan), head_slots, seed_vars,
             ("bind", bind_cap), ("out", out_cap), cap),
            tuple(plan), head_slots, seed_vars, bind_cap, out_cap,
        )
        out, valid, n_d, ov_bind, ov_out = fn(
            state.spo, state.epoch, state.marked, state.tomb,
            state.sorted_keys, state.sort_perm,
            jnp.asarray(atom_consts), jnp.asarray(head_consts),
            seeds_j, valid_j,
        )
        if bool(np.asarray(ov_bind).any()):
            raise CapacityError(self._active_bind_kind)
        if bool(np.asarray(ov_out).any()):
            raise CapacityError(self._active_delta_kind)
        stats.derivations += int(np.asarray(n_d).sum())
        return np.asarray(out).reshape(-1, 3)[np.asarray(valid).reshape(-1)]

    # -- public API ----------------------------------------------------------
    def materialise_state(
        self, facts, program: Program, max_rounds: int = 10_000
    ) -> EngineState:
        """Base REW fixpoint returning a maintainable device-resident state."""
        import time

        t0 = time.perf_counter()
        facts = np.asarray(facts, np.int32).reshape(-1, 3)
        while True:
            try:
                # the base run's early deltas are dataset-sized: delta plans
                # use the full out_cap here, the narrow delta_out on updates
                self._set_update_buffers(False)
                with enable_x64():
                    state = self._fresh_state(program)
                    state.stats.triples_explicit = facts.shape[0]
                    cands, cand_valid = self._pad_cands(facts)
                    self._forward(state, cands, cand_valid, [], max_rounds)
                break
            except CapacityError as e:
                self._grow_for(str(e))
        from .triples import dedup_rows

        state.explicit = dedup_rows(facts)
        self._refresh_stats(state)
        state.stats.wall_seconds += time.perf_counter() - t0
        return state

    def add_facts(
        self, state: EngineState, delta, max_rounds: int = 10_000, retry: bool = True
    ) -> EngineState:
        """Add explicit triples and maintain the store on the accelerator."""
        return self._apply_update(state, "add", delta, max_rounds, retry)

    def delete_facts(
        self, state: EngineState, delta, max_rounds: int = 10_000, retry: bool = True
    ) -> EngineState:
        """Retract explicit triples via the sharded overdelete/rederive pass."""
        return self._apply_update(state, "delete", delta, max_rounds, retry)

    def _apply_update(self, state, op, delta, max_rounds, retry):
        import time

        from .incremental_spmd import spmd_add_facts, spmd_delete_facts

        t0 = time.perf_counter()
        self._maybe_reset_fallback(state)
        while True:
            snap = self._snapshot(state)
            try:
                self._set_update_buffers(True)
                with enable_x64():
                    if op == "add":
                        spmd_add_facts(self, state, delta, max_rounds)
                    else:
                        spmd_delete_facts(self, state, delta, max_rounds)
                break
            except CapacityError as e:
                if not retry:
                    raise
                self._recover_capacity(state, snap, e)
        self._barrier(state)
        state.stats.wall_seconds += time.perf_counter() - t0
        return state

    def materialise_incremental(
        self,
        facts,
        program: Program,
        updates,
        max_rounds: int = 10_000,
        on_device: bool = True,
    ):
        """Base REW materialisation on the accelerator, then maintain the
        result through an update stream without re-running from scratch.

        ``updates`` is an iterable of ``("add" | "delete", delta)`` pairs
        (each delta an (n, 3) int array of explicit triples, original IDs).
        By default both the base fixpoint and the maintenance rounds run on
        this engine (:mod:`repro.core.incremental_spmd`: epoch-tagged
        tombstones + owner-routed delta exchange).  ``on_device=False``
        replays the updates through the host subsystem
        (:mod:`repro.core.incremental`) instead — the reference oracle and
        the baseline bench_incremental compares against.  Returns
        ``(spo, rep, stats)`` like :meth:`materialise`.
        """
        if on_device:
            state = self.materialise_state(facts, program, max_rounds)
            for op, delta in updates:
                if op == "add":
                    self.add_facts(state, delta, max_rounds)
                elif op in ("delete", "del"):
                    self.delete_facts(state, delta, max_rounds)
                else:
                    raise ValueError(f"unknown update op {op!r}")
            return self.state_triples(state), self.state_rep(state), state.stats

        from .incremental import IncrementalState, add_facts, delete_facts
        from .triples import TripleArena, dedup_rows

        spo, rep, stats = self.materialise(facts, program, max_rounds)
        arena = TripleArena()
        arena.add_batch(spo)
        p_cur, _ = program.rewrite(rep)
        host_state = IncrementalState(
            arena=arena,
            rep=rep.astype(np.int32),
            program=p_cur,
            base_program=program,
            explicit=dedup_rows(facts),
            n_resources=self.n_resources,
            stats=stats,
        )
        for op, delta in updates:
            if op == "add":
                add_facts(host_state, delta, max_rounds)
            elif op in ("delete", "del"):
                delete_facts(host_state, delta, max_rounds)
            else:
                raise ValueError(f"unknown update op {op!r}")
        host_state.result()  # refresh triple/memory counters on stats
        return host_state.triples(), host_state.rep, host_state.stats

    def materialise(self, facts, program: Program, max_rounds: int = 10_000):
        """REW materialisation with automatic capacity growth."""
        state = self.materialise_state(facts, program, max_rounds)
        spo = self.state_triples(state)
        return spo, self.state_rep(state), state.stats


# -- audit trace builders (repro.analysis) ----------------------------------
#
# Builders trace each fn family at the CALLER's probe geometry (the supplied
# engine/state), single-device and un-jitted — jaxpr-level invariants are
# about which primitives the fn binds at which shapes, not about how XLA
# compiles them, and the SPMD wrappers only add shard_map plumbing around
# the same body.

def _trace_rule_plans(engine, state, rule, k):
    atom_consts = jnp.zeros((len(rule.body), 3), I32)
    head_consts = jnp.zeros((3,), I32)
    head_slots = tuple(t if is_var(t) else None for t in rule.head)
    for mode, full, tomb in (
        ("delta", False, False), ("full", True, False), ("tomb", False, True),
    ):
        for i, plan in enumerate(build_plans(rule, full=full, tombstone=tomb)):
            fn = partial(
                eval_plan, plan=tuple(plan), head_var_slots=head_slots,
                bind_cap=engine.bind_cap, out_cap=engine.out_cap, axis=None,
                use_kernel=engine.use_kernel,
            )
            jx = jax.make_jaxpr(fn)(
                state.spo, state.epoch, state.marked, state.tomb,
                state.sorted_keys, state.sort_perm,
                jnp.asarray(1, I32), atom_consts, head_consts,
            )
            yield f"plan:rule{k}:{mode}:{i}", jx


@register_auditable("plan")
def _audit_plan(engine, state):
    for k, rule in enumerate(state.program.rules):
        yield from _trace_rule_plans(engine, state, rule, k)


@register_auditable("rplan")
def _audit_rplan(engine, state):
    for k, rule in enumerate(state.program.rules):
        plan, seed_vars = build_rederive_plan(rule)
        if not seed_vars:
            continue  # variable-free head: whole-rule requeue fallback
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        fn = partial(
            eval_plan_rederive, plan=tuple(plan), head_var_slots=head_slots,
            seed_vars=seed_vars, bind_cap=engine.bind_cap,
            out_cap=engine.out_cap, axis=None, use_kernel=engine.use_kernel,
        )
        jx = jax.make_jaxpr(fn)(
            state.spo, state.epoch, state.marked, state.tomb,
            state.sorted_keys, state.sort_perm,
            jnp.zeros((len(rule.body), 3), I32), jnp.zeros((3,), I32),
            jnp.zeros((64, len(seed_vars)), I32), jnp.zeros((64,), bool),
        )
        yield f"rplan:rule{k}", jx


@register_auditable("mplan")
def _audit_mplan(engine, state):
    # one trace per (rule, anchor) the forward-side targeted re-merge can
    # dispatch: any body atom with a variable can be the changed anchor
    # (ground anchors fall back to the whole-rule "plan" full mode)
    for k, rule in enumerate(state.program.rules):
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        for anchor in range(len(rule.body)):
            if not any(is_var(t) for t in rule.body[anchor]):
                continue
            plan = build_merge_plan(rule, anchor)
            fn = partial(
                eval_plan, plan=tuple(plan), head_var_slots=head_slots,
                bind_cap=engine.bind_cap, out_cap=engine.out_cap, axis=None,
                use_kernel=engine.use_kernel,
            )
            jx = jax.make_jaxpr(fn)(
                state.spo, state.epoch, state.marked, state.tomb,
                state.sorted_keys, state.sort_perm, jnp.asarray(1, I32),
                jnp.zeros((len(rule.body), 3), I32), jnp.zeros((3,), I32),
            )
            yield f"mplan:rule{k}:anchor{anchor}", jx


@register_auditable("process")
def _audit_process(engine, state):
    fn = partial(
        process_candidates, rewrite_cap=engine.rewrite_cap, axis=None,
        n_shards=1, route_cap=None, pair_cap=engine.pair_cap,
        use_kernel=engine.use_kernel,
    )
    cands = jnp.zeros((engine.out_cap, 3), I32)
    cv = jnp.zeros((engine.out_cap,), bool)
    jx = jax.make_jaxpr(fn)(
        state.spo, state.epoch, state.marked, state.n_used, state.rep,
        state.sort_perm, state.sorted_keys, cands, cv, jnp.asarray(1, I32),
    )
    yield "process", jx


@register_auditable("squeeze")
def _audit_squeeze(engine, state):
    wide = 2 * engine.out_cap
    fn = partial(_squeeze_stream, target=engine.out_cap)
    jx = jax.make_jaxpr(fn)(
        jnp.zeros((wide, 3), I32), jnp.zeros((wide,), bool),
    )
    yield "squeeze", jx


@register_auditable("rebuild_index", skip_passes=("NoArenaSort",))
def _audit_rebuild_index(engine, state):
    # the ONE allowed arena argsort (<= once per mutation epoch, counted by
    # stats.index_rebuilds) — exempt from NoArenaSort by design
    jx = jax.make_jaxpr(_rebuild_index)(state.spo, state.epoch, state.marked)
    yield "rebuild_index", jx


@register_auditable("snapshot", skip_passes=("NoArenaSort",))
def _audit_snapshot(engine, state):
    # the per-barrier publication step of the serving tier: derives the
    # secondary (p,o,s)-ordered snapshot view with one argsort — a counted
    # per-epoch cost OFF the query path (docs/serving.md), exempt from
    # NoArenaSort exactly like the index rebuild it mirrors
    jx = jax.make_jaxpr(_publish_snapshot)(
        state.spo, state.sort_perm, state.sorted_keys
    )
    yield "snapshot", jx
