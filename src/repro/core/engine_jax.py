"""Fixed-capacity JAX materialisation engine (REW mode) — the production path.

The numpy engine in :mod:`repro.core.seminaive` is the flexible reference
oracle; this module is the TPU-shaped implementation: every buffer has a
static capacity, every step is a pure jittable function, and the same round
body runs single-device or SPMD under ``shard_map`` (pass ``mesh=``).

Design (DESIGN.md §2):
  * store  = arena ``spo (CAP,3) int32`` + ``epoch (CAP,) int32`` (-1 = free,
    else the round the fact was inserted) + ``marked (CAP,) bool`` (the
    paper's outdated bit; marked facts are skipped by matching but retained),
  * delta discipline via epochs: round r matches Delta = (epoch == r-1),
    T_old = (epoch <= r-2), T_all = (epoch <= r-1),
  * joins  = sort + searchsorted over packed int64 keys with static output
    capacities and overflow flags (host retries with doubled capacity),
  * rho    = replicated representative array; merges via
    :func:`repro.core.uf.merge_pairs_jax` (min-hooking + pointer doubling),
  * rule rewriting happens on the host at the round barrier; rule *constants*
    are traced arguments, so rewriting a rule never re-traces its plan.

Distribution (the paper's N threads -> mesh ``data`` axis):
  * the arena is sharded by rows; a fact lives on shard ``subject % D``,
  * plan evaluation joins replicated bindings against the local shard and
    ``all_gather``s bindings between atoms (new sameAs pairs and candidate
    heads are few relative to the store — the paper's own observation),
  * rho is replicated and updated identically on every shard (min-hooking is
    order-independent, so no coordination is needed — the paper needed CAS),
  * candidate facts and sweep rewrites are re-routed to their owner shard by
    the gather + ownership filter (the all_to_all analogue),
  * convergence flags are psum'd.

Everything runs inside an ``enable_x64`` scope because packed triple keys
need 63 bits; inputs/outputs stay int32.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as compat_shard_map

from .rules import Program, Rule
from .stats import MatStats
from .terms import DIFFERENT_FROM, SAME_AS, is_var
from .uf import compress_np, merge_pairs_jax

with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    from jax.experimental import enable_x64

I32 = jnp.int32
# numpy scalar (not jnp): module import happens outside the enable_x64 scope
KEY_MAX = np.int64((1 << 63) - 1)  # > any packed key (IDs <= MAX_ID)

# epoch predicates for matching
PRED_OLD, PRED_DELTA, PRED_ALL = 0, 1, 2


def _pack3(spo: jnp.ndarray) -> jnp.ndarray:
    s = spo[..., 0].astype(jnp.int64)
    p = spo[..., 1].astype(jnp.int64)
    o = spo[..., 2].astype(jnp.int64)
    return (s << 42) | (p << 21) | o


def _pack_cols(cols: list[jnp.ndarray]) -> jnp.ndarray:
    key = jnp.zeros(cols[0].shape, dtype=jnp.int64)
    for c in cols:
        key = (key << 21) | c.astype(jnp.int64)
    return key


def _epoch_ok(epoch: jnp.ndarray, marked: jnp.ndarray, r, pred: int) -> jnp.ndarray:
    live = (epoch >= 0) & ~marked
    if pred == PRED_OLD:
        return live & (epoch <= r - 2)
    if pred == PRED_DELTA:
        return live & (epoch == r - 1)
    return live & (epoch <= r - 1)


def _match_atom(spo, ok, consts, const_mask, eq_pairs):
    """const_mask/eq_pairs are static; consts is a traced (3,) int32."""
    for pos in range(3):
        if const_mask[pos]:
            ok = ok & (spo[:, pos] == consts[pos])
    for a, b in eq_pairs:
        ok = ok & (spo[:, a] == spo[:, b])
    return ok


def _compact(cols: dict, valid: jnp.ndarray, cap: int):
    """Pack valid rows to the front, truncating at ``cap``."""
    order = jnp.argsort(~valid, stable=True)[:cap]
    n_valid = valid.sum()
    out_valid = jnp.arange(cap) < n_valid
    out_cols = {v: c[order] for v, c in cols.items()}
    overflow = n_valid > cap
    return out_cols, out_valid, overflow


def _expand_join(cols, valid, spo, ok, bound_items, free_items, out_cap):
    """Join bindings against (spo, ok) on ``bound_items``; static structure.

    bound_items: list of (var, atom_pos) already present in ``cols``.
    free_items:  list of (var, atom_pos) newly bound by this atom.
    """
    if bound_items:
        skey = _pack_cols([spo[:, pos] for _, pos in bound_items])
        bkey = _pack_cols([cols[v] for v, _ in bound_items])
    else:
        skey = jnp.zeros(spo.shape[0], dtype=jnp.int64)
        bkey = jnp.zeros(valid.shape[0], dtype=jnp.int64)
    skey = jnp.where(ok, skey, KEY_MAX)
    order = jnp.argsort(skey)
    skey_s = skey[order]
    bkey = jnp.where(valid, bkey, KEY_MAX - 1)
    lo = jnp.searchsorted(skey_s, bkey, side="left")
    hi = jnp.searchsorted(skey_s, bkey, side="right")
    counts = jnp.where(valid, hi - lo, 0)
    cum = jnp.cumsum(counts) - counts  # exclusive
    total = counts.sum()
    j = jnp.arange(out_cap)
    seg = jnp.searchsorted(cum, j, side="right") - 1
    seg = jnp.clip(seg, 0, valid.shape[0] - 1)
    within = j - cum[seg]
    srow = order[jnp.clip(lo[seg] + within, 0, spo.shape[0] - 1)]
    out_valid = j < total
    new_cols = {v: jnp.where(out_valid, cols[v][seg], 0) for v in cols}
    for v, pos in free_items:
        new_cols[v] = jnp.where(out_valid, spo[srow, pos], 0)
    return new_cols, out_valid, total > out_cap, total


@dataclass(frozen=True)
class _AtomSpec:
    """Static structure of one body atom within a plan."""

    index: int
    const_mask: tuple[bool, bool, bool]
    eq_pairs: tuple[tuple[int, int], ...]
    bound_items: tuple[tuple[int, int], ...]
    free_items: tuple[tuple[int, int], ...]
    pred: int
    count_appl: bool = False  # this atom feeds the 'Rule appl.' counter


def _atom_static(atom, bound_vars: set[int]):
    const_mask = tuple(not is_var(t) for t in atom)
    eq_pairs = []
    first_pos: dict[int, int] = {}
    for pos, t in enumerate(atom):
        if is_var(t):
            if t in first_pos:
                eq_pairs.append((first_pos[t], pos))
            else:
                first_pos[t] = pos
    bound = tuple((v, p) for v, p in first_pos.items() if v in bound_vars)
    free = tuple((v, p) for v, p in first_pos.items() if v not in bound_vars)
    return const_mask, tuple(eq_pairs), bound, free


def build_plans(rule: Rule, full: bool) -> list[list[_AtomSpec]]:
    """Delta plans (or the single full-evaluation plan) of a rule."""
    plans = []
    delta_positions = [0] if full else list(range(len(rule.body)))
    for i in delta_positions:
        specs = []
        bound: set[int] = set()
        for j, atom in enumerate(rule.body):
            const_mask, eq_pairs, b, f = _atom_static(atom, bound)
            if full:
                pred = PRED_ALL
            else:
                pred = PRED_OLD if j < i else (PRED_DELTA if j == i else PRED_ALL)
            count_appl = (pred == PRED_DELTA) or (full and j == 0)
            specs.append(_AtomSpec(j, const_mask, eq_pairs, b, f, pred, count_appl))
            bound |= {v for v, _ in b} | {v for v, _ in f}
        plans.append(specs)
    return plans


def _gather(x, axis):
    return jax.lax.all_gather(x, axis, tiled=True)


def eval_plan(
    spo,
    epoch,
    marked,
    r,
    atom_consts,  # (n_atoms, 3) traced rule constants (vars hold garbage 0)
    head_consts,  # (3,) traced
    plan: tuple,  # static tuple of _AtomSpec
    head_var_slots: tuple,  # static: per head position, var id or None
    bind_cap: int,
    out_cap: int,
    axis: str | None = None,
):
    """Evaluate one delta plan; returns (heads (out_cap,3), valid, stats...).

    Under SPMD (``axis`` set): each atom joins against the *local* store
    shard; bindings are all_gathered between atoms so every shard sees the
    global binding table.  The final join's results stay local — their union
    over shards is the global candidate set.
    """
    cols: dict[int, jnp.ndarray] = {}
    valid = jnp.ones((1,), dtype=bool)  # the unit binding
    n_appl = jnp.zeros((), I32)
    overflow = jnp.zeros((), bool)
    for step, spec in enumerate(plan):
        ok = _epoch_ok(epoch, marked, r, spec.pred)
        ok = _match_atom(spo, ok, atom_consts[spec.index], spec.const_mask, spec.eq_pairs)
        if spec.count_appl:
            n_appl = n_appl + ok.sum().astype(I32)
        if step == 0 and not spec.bound_items:
            # initial scan: bindings = matching rows directly (no join needed)
            cols = {v: jnp.where(ok, spo[:, p], 0) for v, p in spec.free_items}
            valid = ok
            cols, valid, ov = _compact(cols, valid, bind_cap)
            overflow |= ov
        else:
            cols, valid, ov, _ = _expand_join(
                cols, valid, spo, ok, spec.bound_items, spec.free_items, bind_cap
            )
            overflow |= ov
        if axis is not None and step < len(plan) - 1:
            cols = {v: _gather(c, axis) for v, c in cols.items()}
            valid = _gather(valid, axis)
    # instantiate head
    heads = []
    for pos in range(3):
        v = head_var_slots[pos]
        if v is None:
            heads.append(jnp.broadcast_to(head_consts[pos], valid.shape).astype(I32))
        else:
            heads.append(cols[v].astype(I32))
    out = jnp.stack(heads, axis=1)
    outc, out_valid, ov = _compact(
        {"s": out[:, 0], "p": out[:, 1], "o": out[:, 2]}, valid, out_cap
    )
    out = jnp.stack([outc["s"], outc["p"], outc["o"]], axis=1)
    n_deriv = out_valid.sum().astype(I32)
    return out, out_valid, n_deriv[None], n_appl[None], (overflow | ov)[None]


def process_candidates(
    spo,
    epoch,
    marked,
    n_used,
    rep,
    cands,
    cand_valid,
    r,
    rewrite_cap: int,
    axis: str | None = None,
    n_shards: int = 1,
    route_cap: int | None = None,
    pair_cap: int = 4096,
):
    """Normalise, merge equalities, sweep, insert — the state-update half of a
    round (Algorithms 3-6 in bulk).  Pure; runs per-shard under shard_map.

    Under SPMD there are two exchange schemes:

      * ``route_cap=None`` (baseline): candidates are ALL-GATHERED so every
        shard sees/sorts the global padded stream; an ownership mask
        (``subject % n_shards``) picks the inserting shard.  The per-shard
        sort is O(n_shards x out_cap x 4) — 33.5M rows on the 256-chip
        round_268m cell, 99% padding (measured, §Perf).
      * ``route_cap=k`` (owner routing — the bulk analogue of the paper's
        per-thread insertion into the shared store): each shard expands its
        OWN candidates (rewrites + reflexivity), then routes every row to
        its owner with one all_to_all of (n_shards, k) buckets.  Only the
        few global sameAs pairs are still all-gathered (rho must update
        identically everywhere).  Per-shard sort shrinks to
        n_shards x route_cap rows and the exchange moves bucket payloads
        instead of the padded stream.  Bucket overflow raises the engine's
        capacity-retry (host doubles ``route_cap``).
    """
    arena_cap = spo.shape[0] - 1  # last row is the scatter trash slot
    n_used = n_used.reshape(())
    routed = axis is not None and route_cap is not None
    route_overflow = jnp.zeros((), bool)

    if axis is not None and not routed:
        cands = _gather(cands, axis)
        cand_valid = _gather(cand_valid, axis)

    # 1) normalise with current rho
    cands = jnp.where(cand_valid[:, None], rep[cands], 0).astype(I32)

    # 2) merge sameAs pairs (deterministic min-hooking -> identical on shards)
    is_pair = cand_valid & (cands[:, 1] == SAME_AS) & (cands[:, 0] != cands[:, 2])
    if routed:
        # pairs are few: compact locally, gather the compacted buffer
        n_pairs = jax.lax.psum(is_pair.sum().astype(I32), axis)
        pcols, pvalid, p_ov = _compact(
            {"a": cands[:, 0], "b": cands[:, 2]}, is_pair, pair_cap
        )
        route_overflow |= p_ov
        pairs = _gather(jnp.stack([pcols["a"], pcols["b"]], axis=1), axis)
        pair_valid = _gather(pvalid, axis)
    else:
        pairs = jnp.stack([cands[:, 0], cands[:, 2]], axis=1)
        pair_valid = is_pair
        n_pairs = is_pair.sum().astype(I32)
    new_rep = merge_pairs_jax(rep, pairs, pair_valid)
    rep_changed = jnp.any(new_rep != rep)
    rep = new_rep

    # 3) re-normalise candidates under the new rho
    cands = jnp.where(cand_valid[:, None], rep[cands], 0).astype(I32)

    # 4) sweep the local store shard (bulk Algorithm 3)
    live = (epoch >= 0) & ~marked
    rewritten = rep[spo].astype(I32)
    changed = live & jnp.any(rewritten != spo, axis=1)
    marked = marked | changed
    rw_cols, rw_valid, rw_overflow = _compact(
        {"s": rewritten[:, 0], "p": rewritten[:, 1], "o": rewritten[:, 2]},
        changed,
        rewrite_cap,
    )
    rw = jnp.stack([rw_cols["s"], rw_cols["p"], rw_cols["o"]], axis=1)
    if axis is not None and not routed:
        rw = _gather(rw, axis)
        rw_valid = _gather(rw_valid, axis)

    all_c = jnp.concatenate([cands, rw], axis=0)
    all_v = jnp.concatenate([cand_valid, rw_valid], axis=0)

    # 5) contradiction check (~=5) on normal forms — pre-ownership, so every
    # shard reports the same verdict
    contradiction = jnp.any(
        all_v & (all_c[:, 1] == DIFFERENT_FROM) & (all_c[:, 0] == all_c[:, 2])
    )
    if routed:  # local verdicts -> identical global verdict
        contradiction = jax.lax.psum(contradiction.astype(I32), axis) > 0

    # 6) reflexivity (Algorithm 4 lines 17-18): <c, sameAs, c> for each
    # resource of each candidate, plus <sameAs,sameAs,sameAs>
    res = all_c.reshape(-1)
    res_valid = jnp.repeat(all_v, 3)
    refl = jnp.stack([res, jnp.full_like(res, SAME_AS), res], axis=1)
    sa_row = jnp.asarray([[SAME_AS, SAME_AS, SAME_AS]], dtype=I32)
    any_v = jnp.any(all_v)
    stream = jnp.concatenate([all_c, refl, sa_row], axis=0)
    stream_v = jnp.concatenate([all_v, res_valid, any_v[None]], axis=0)
    # origin flag: True for rows created by the reflexivity expansion (so a
    # rule-derived reflexive fact is booked as a rule derivation, not here;
    # stable sort keeps the candidate occurrence on duplicates)
    stream_refl = jnp.concatenate(
        [jnp.zeros(all_c.shape[0], bool), jnp.ones(res.shape[0] + 1, bool)]
    )

    # ownership: a row is inserted only by shard ``subject % n_shards``
    if routed:
        # route rows to their owners: one all_to_all of (n_shards, route_cap)
        # buckets replaces sorting the global padded stream on every shard
        owner = (stream[:, 0] % n_shards).astype(I32)
        okey = jnp.where(stream_v, owner, n_shards)
        order_r = jnp.argsort(okey, stable=True).astype(I32)
        so = okey[order_r]
        starts = jnp.searchsorted(so, jnp.arange(n_shards, dtype=I32)).astype(I32)
        pos = jnp.arange(so.shape[0], dtype=I32) - starts[jnp.clip(so, 0, n_shards - 1)]
        keep = (so < n_shards) & (pos < route_cap)
        route_overflow |= jnp.any((so < n_shards) & (pos >= route_cap))
        payload = jnp.concatenate(
            [
                stream[order_r],
                stream_refl[order_r, None].astype(I32),
                keep[:, None].astype(I32),
            ],
            axis=1,
        )  # (N, 5): s, p, o, refl, valid
        buckets = jnp.zeros((n_shards, route_cap, 5), I32)
        tgt_shard = jnp.where(keep, so, 0)
        tgt_slot = jnp.where(keep, pos, route_cap)  # out-of-range -> dropped
        buckets = buckets.at[tgt_shard, tgt_slot].set(
            jnp.where(keep[:, None], payload, 0), mode="drop"
        )
        recv = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0, tiled=True)
        stream = recv[..., :3].reshape(-1, 3)
        stream_refl = recv[..., 3].reshape(-1).astype(bool)
        stream_v = recv[..., 4].reshape(-1).astype(bool)
    elif axis is not None:
        own = (stream[:, 0] % n_shards) == jax.lax.axis_index(axis)
        stream_v = stream_v & own

    # 7) dedup within the stream
    skeys = jnp.where(stream_v, _pack3(stream), KEY_MAX)
    order = jnp.argsort(skeys, stable=True)
    sk = skeys[order]
    uniq = jnp.concatenate([jnp.asarray([True]), sk[1:] != sk[:-1]])
    uniq = uniq & (sk < KEY_MAX)

    # 8) membership against live local store rows
    live = (epoch >= 0) & ~marked
    store_keys = jnp.where(live, _pack3(spo), KEY_MAX)
    sorder = jnp.argsort(store_keys)
    sks = store_keys[sorder]
    pos = jnp.searchsorted(sks, sk)
    member = sks[jnp.clip(pos, 0, spo.shape[0] - 1)] == sk
    fresh = uniq & ~member

    # 9) scatter fresh rows into free local slots
    n_fresh = fresh.sum().astype(I32)
    slot = n_used + jnp.cumsum(fresh) - 1
    insert_overflow = (n_used + n_fresh) > arena_cap
    tgt = jnp.where(fresh, jnp.minimum(slot, arena_cap), arena_cap)
    rows = stream[order]
    spo = spo.at[tgt].set(jnp.where(fresh[:, None], rows, spo[tgt]))
    epoch = epoch.at[tgt].set(jnp.where(fresh, r, epoch[tgt]))
    # the trash row must stay dead no matter what was scattered into it
    spo = spo.at[arena_cap].set(0)
    epoch = epoch.at[arena_cap].set(-1)
    n_used = n_used + n_fresh

    # reflexive-added stat: fresh rows originating from the reflexivity step
    is_refl = fresh & stream_refl[order]
    n_refl = is_refl.sum().astype(I32)

    flags = {
        "rep_changed": rep_changed,
        "contradiction": contradiction,
        "overflow": (rw_overflow | insert_overflow | route_overflow)[None],
        "n_new": n_fresh[None],
        "n_pairs": n_pairs,
        "n_marked": changed.sum().astype(I32)[None],
        "n_reflexive": n_refl[None],
    }
    return spo, epoch, marked, n_used[None], rep, flags


class CapacityError(RuntimeError):
    pass


class JaxEngine:
    """REW materialisation with static capacities; single-device or SPMD.

    Pass ``mesh`` (a 1-D ``jax.sharding.Mesh`` whose axis shards the arena)
    to run distributed; capacities are then per shard.  ``materialise``
    retries with doubled capacities on overflow, so callers normally never
    see :class:`CapacityError`.
    """

    def __init__(
        self,
        n_resources: int,
        capacity: int = 1 << 12,
        bind_cap: int = 1 << 12,
        out_cap: int = 1 << 12,
        rewrite_cap: int = 1 << 12,
        mesh=None,
        axis: str = "data",
        route_cap: int | None = None,
    ) -> None:
        self.n_resources = n_resources
        self.capacity = capacity
        self.bind_cap = bind_cap
        self.out_cap = out_cap
        self.rewrite_cap = rewrite_cap
        self.route_cap = route_cap
        self.mesh = mesh
        self.axis = axis if mesh is not None else None
        self.n_shards = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
        self._fns: dict = {}

    # -- jit wrappers -------------------------------------------------------
    def _wrap(self, fn, in_specs, out_specs):
        if self.mesh is None:
            return jax.jit(fn)
        return jax.jit(
            compat_shard_map(
                fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs,
            )
        )

    def _get_plan_fn(self, plan_key, plan, head_slots):
        if plan_key not in self._fns:
            a = self.axis
            fn = partial(
                eval_plan,
                plan=plan,
                head_var_slots=head_slots,
                bind_cap=self.bind_cap,
                out_cap=self.out_cap,
                axis=a,
            )
            d = P(a) if a else None
            rpl = P() if a else None
            self._fns[plan_key] = self._wrap(
                fn,
                in_specs=(d, d, d, rpl, rpl, rpl),
                out_specs=(d, d, d, d, d),
            )
        return self._fns[plan_key]

    def _get_process_fn(self, n_cand_rows: int):
        key = ("process", n_cand_rows)
        if key not in self._fns:
            a = self.axis
            fn = partial(
                process_candidates,
                rewrite_cap=self.rewrite_cap,
                axis=a,
                n_shards=self.n_shards,
                route_cap=self.route_cap if a is not None else None,
                pair_cap=min(self.out_cap, 4096),
            )
            d = P(a) if a else None
            rpl = P() if a else None
            flag_specs = {
                "rep_changed": rpl,
                "contradiction": rpl,
                "overflow": d,
                "n_new": d,
                "n_pairs": rpl,
                "n_marked": d,
                "n_reflexive": d,
            }
            self._fns[key] = self._wrap(
                fn,
                in_specs=(d, d, d, d, rpl, d, d, rpl),
                out_specs=(d, d, d, d, rpl, flag_specs),
            )
        return self._fns[key]

    # -- driver --------------------------------------------------------------
    def _run(self, facts: np.ndarray, program: Program, max_rounds: int):
        stats = MatStats(mode="REW-jax" + ("-spmd" if self.mesh is not None else ""))
        cap, D = self.capacity, self.n_shards
        spo = jnp.zeros(((cap + 1) * D, 3), I32)
        epoch = jnp.full(((cap + 1) * D,), -1, I32)
        marked = jnp.zeros(((cap + 1) * D,), bool)
        n_used = jnp.zeros((D,), I32)
        rep = jnp.arange(self.n_resources, dtype=I32)

        p_cur = program
        requeued: list[int] = []

        facts = np.asarray(facts, np.int32).reshape(-1, 3)
        stats.triples_explicit = facts.shape[0]
        rows_global = self.out_cap * D
        if facts.shape[0] > rows_global:
            raise CapacityError("out_cap")
        pad = rows_global - facts.shape[0]
        cands = jnp.asarray(np.pad(facts, ((0, pad), (0, 0))), I32)
        cand_valid = jnp.asarray(np.arange(rows_global) < facts.shape[0])

        r = 0
        have_cands = True
        while have_cands or requeued:
            r += 1
            stats.rounds += 1
            if r > max_rounds:
                raise RuntimeError("did not converge")
            proc = self._get_process_fn(int(cands.shape[0]))
            spo, epoch, marked, n_used, rep_new, flags = proc(
                spo, epoch, marked, n_used, rep, cands, cand_valid, jnp.asarray(r, I32)
            )
            if bool(np.asarray(flags["overflow"]).any()):
                raise CapacityError("store/rewrite")
            if bool(np.asarray(flags["contradiction"]).reshape(-1)[0]):
                from .materialise import Contradiction

                raise Contradiction("owl:differentFrom violation")
            stats.sameas_pairs += int(np.asarray(flags["n_pairs"]).reshape(-1)[0])
            n_refl = int(np.asarray(flags["n_reflexive"]).sum())
            stats.reflexive_added += n_refl
            stats.derivations += n_refl

            rep_changed = bool(np.asarray(flags["rep_changed"]).reshape(-1)[0])
            if rep_changed:
                rep_host = compress_np(np.asarray(rep_new))
                p_new, changed_idx = p_cur.rewrite(rep_host)
                if changed_idx:
                    stats.rule_rewrites += 1
                    stats.rules_requeued += len(changed_idx)
                    requeued.extend(changed_idx)
                p_cur = p_new
            rep = rep_new

            # evaluate plans for the new delta
            bufs = []
            n_new = int(np.asarray(flags["n_new"]).sum())
            if n_new > 0:
                for k, rule in enumerate(p_cur.rules):
                    bufs += self._eval_rule(spo, epoch, marked, r + 1, rule, k, False, stats)
            for k in sorted(set(requeued)):
                bufs += self._eval_rule(spo, epoch, marked, r + 1, p_cur.rules[k], k, True, stats)
            requeued = []
            if bufs:
                cands = jnp.concatenate([b[0] for b in bufs], axis=0)
                cand_valid = jnp.concatenate([b[1] for b in bufs], axis=0)
                have_cands = bool(cand_valid.any())
            else:
                have_cands = False

        stats.merged_resources = int(
            (compress_np(np.asarray(rep)) != np.arange(self.n_resources)).sum()
        )
        stats.triples_total = int(np.asarray(n_used).sum())
        return spo, epoch, marked, rep, p_cur, stats

    def _eval_rule(self, spo, epoch, marked, r, rule: Rule, k: int, full: bool, stats: MatStats):
        atom_consts = np.zeros((len(rule.body), 3), np.int32)
        for j, atom in enumerate(rule.body):
            for pos, t in enumerate(atom):
                atom_consts[j, pos] = 0 if is_var(t) else t
        head_consts = np.asarray([0 if is_var(t) else t for t in rule.head], np.int32)
        head_slots = tuple(t if is_var(t) else None for t in rule.head)
        plans = build_plans(rule, full=full)
        out = []
        for i, plan in enumerate(plans):
            plan_t = tuple(plan)
            fn = self._get_plan_fn(("plan", k, i, full, plan_t, head_slots), plan_t, head_slots)
            heads, valid, n_d, n_a, ov = fn(
                spo, epoch, marked, jnp.asarray(r, I32),
                jnp.asarray(atom_consts), jnp.asarray(head_consts),
            )
            if bool(np.asarray(ov).any()):
                raise CapacityError("bind/out")
            stats.derivations += int(np.asarray(n_d).sum())
            stats.rule_applications += int(np.asarray(n_a).sum())
            out.append((heads, valid))
        return out

    def materialise_incremental(
        self, facts, program: Program, updates, max_rounds: int = 10_000
    ):
        """Base REW materialisation on the accelerator, then maintain the
        result through an update stream without re-running from scratch.

        ``updates`` is an iterable of ``("add" | "delete", delta)`` pairs
        (each delta an (n, 3) int array of explicit triples, original IDs).
        The base fixpoint — the expensive part — runs on this engine; the
        maintenance passes run on the host subsystem
        (:mod:`repro.core.incremental`), which shares the rho/arena/rule
        machinery and is oracle-equal to a from-scratch run.  Returns
        ``(spo, rep, stats)`` like :meth:`materialise`.
        """
        from .incremental import IncrementalState, add_facts, delete_facts
        from .triples import TripleArena, dedup_rows

        spo, rep, stats = self.materialise(facts, program, max_rounds)
        arena = TripleArena()
        arena.add_batch(spo)
        p_cur, _ = program.rewrite(rep)
        state = IncrementalState(
            arena=arena,
            rep=rep.astype(np.int32),
            program=p_cur,
            base_program=program,
            explicit=dedup_rows(facts),
            n_resources=self.n_resources,
            stats=stats,
        )
        for op, delta in updates:
            if op == "add":
                add_facts(state, delta, max_rounds)
            elif op in ("delete", "del"):
                delete_facts(state, delta, max_rounds)
            else:
                raise ValueError(f"unknown update op {op!r}")
        state.result()  # refresh triple/memory counters on stats
        return state.triples(), state.rep, state.stats

    def materialise(self, facts, program: Program, max_rounds: int = 10_000):
        """REW materialisation with automatic capacity growth."""
        import time

        t0 = time.perf_counter()
        while True:
            try:
                with enable_x64():
                    spo, epoch, marked, rep, p_cur, stats = self._run(
                        facts, program, max_rounds
                    )
                break
            except CapacityError:
                self.capacity *= 2
                self.bind_cap *= 2
                self.out_cap *= 2
                self.rewrite_cap *= 2
                if self.route_cap is not None:
                    self.route_cap *= 2
                self._fns.clear()
        stats.wall_seconds = time.perf_counter() - t0
        spo_h = np.asarray(spo)
        epoch_h = np.asarray(epoch)
        marked_h = np.asarray(marked)
        live = (epoch_h >= 0) & ~marked_h
        stats.triples_unmarked = int(live.sum())
        rep_h = compress_np(np.asarray(rep))
        return spo_h[live], rep_h, stats
