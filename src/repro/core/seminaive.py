"""Batched semi-naive rule evaluation (reference engine).

The paper uses a fact-at-a-time semi-naive algorithm whose ``T^{<F}/T^{<=F}``
annotated queries guarantee each (rule, substitution) pair is considered at
most once (Claim 7).  The batched equivalent used here is the standard
round-stratified discipline: for a rule with body atoms B1..Bn, round r
evaluates n *delta plans*; plan i matches

    atoms j < i  against T_old      (facts from earlier rounds),
    atom  i      against Delta      (facts added last round),
    atoms j > i  against T_old u Delta,

which assigns every new substitution to exactly one (round, plan) — the bulk
analogue of the paper's annotation trick (DESIGN.md S2).

Joins are sort-merge: pack the bound positions of an atom into int64 keys,
sort the candidate triples once, ``searchsorted`` the binding rows, and expand
match ranges with the cumsum trick.  This is the SIMD-friendly replacement for
RDFox's hash indexes and is the same algorithm the JAX/TPU engine uses with
static capacities (:mod:`repro.core.engine_jax`).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .rules import Rule
from .terms import is_var


@dataclass
class Bindings:
    """Columnar substitution table: var id -> value column."""

    cols: dict[int, np.ndarray]
    nrows: int

    @staticmethod
    def empty_universe() -> "Bindings":
        """A single empty substitution (the unit of the join)."""
        return Bindings({}, 1)

    def select(self, idx: np.ndarray) -> "Bindings":
        return Bindings({v: c[idx] for v, c in self.cols.items()}, idx.shape[0])


def _pack_cols(cols: list[np.ndarray]) -> np.ndarray:
    """Pack up to 3 int32 columns into one int64 key."""
    key = np.zeros(cols[0].shape[0], dtype=np.int64)
    for c in cols:
        key = (key << 21) | c.astype(np.int64)
    return key


def _const_filter(atom, triples: np.ndarray) -> np.ndarray:
    """Rows of ``triples`` compatible with the atom's constants and
    intra-atom repeated variables."""
    mask = np.ones(triples.shape[0], dtype=bool)
    seen: dict[int, int] = {}
    for pos, t in enumerate(atom):
        if not is_var(t):
            mask &= triples[:, pos] == t
        else:
            if t in seen:
                mask &= triples[:, pos] == triples[:, seen[t]]
            else:
                seen[t] = pos
    return mask


def join_atom(
    bindings: Bindings, atom, triples: np.ndarray
) -> tuple[Bindings, int]:
    """Extend ``bindings`` with matches of ``atom`` against ``triples``.

    Returns (new bindings, number of candidate triples matched by the atom's
    constant pattern) — the latter feeds the 'rule applications' counter when
    the atom is the delta atom.
    """
    mask = _const_filter(atom, triples)
    cand = triples[mask]
    n_cand = cand.shape[0]

    # variable positions (first occurrence only)
    var_pos: dict[int, int] = {}
    for pos, t in enumerate(atom):
        if is_var(t) and t not in var_pos:
            var_pos[t] = pos

    bound = [v for v in var_pos if v in bindings.cols]
    free = [v for v in var_pos if v not in bindings.cols]

    if bindings.nrows == 0 or n_cand == 0:
        cols = {v: np.zeros(0, dtype=np.int32) for v in bindings.cols}
        for v in free:
            cols[v] = np.zeros(0, dtype=np.int32)
        return Bindings(cols, 0), n_cand

    if not bound:
        # cartesian product
        nb, nc = bindings.nrows, n_cand
        row_ids = np.repeat(np.arange(nb), nc)
        cand_ids = np.tile(np.arange(nc), nb)
    else:
        ck = _pack_cols([cand[:, var_pos[v]] for v in bound])
        order = np.argsort(ck, kind="stable")
        ck_sorted = ck[order]
        bk = _pack_cols([bindings.cols[v] for v in bound])
        lo = np.searchsorted(ck_sorted, bk, side="left")
        hi = np.searchsorted(ck_sorted, bk, side="right")
        counts = hi - lo
        total = int(counts.sum())
        row_ids = np.repeat(np.arange(bindings.nrows), counts)
        if total:
            cum = np.concatenate([[0], np.cumsum(counts)[:-1]])
            within = np.arange(total) - np.repeat(cum, counts)
            cand_ids = order[lo[row_ids] + within]
        else:
            cand_ids = np.zeros(0, dtype=np.int64)

    out = bindings.select(row_ids)
    for v in free:
        out.cols[v] = cand[cand_ids, var_pos[v]].astype(np.int32)
    return out, n_cand


def instantiate_head(head, bindings: Bindings) -> np.ndarray:
    cols = []
    for t in head:
        if is_var(t):
            cols.append(bindings.cols[t])
        else:
            cols.append(np.full(bindings.nrows, t, dtype=np.int32))
    if bindings.nrows == 0:
        return np.zeros((0, 3), dtype=np.int32)
    return np.stack(cols, axis=1)


def eval_rule_delta(
    rule: Rule,
    t_old: np.ndarray,
    t_all: np.ndarray,
    delta: np.ndarray,
) -> tuple[np.ndarray, int, int]:
    """All delta plans of one rule for one round.

    Returns (derived head facts (m,3) with duplicates, n_derivations,
    n_rule_applications).
    """
    heads: list[np.ndarray] = []
    n_deriv = 0
    n_appl = 0
    body = rule.body
    for i in range(len(body)):
        # delta-first join order: plans whose delta atom matches nothing die
        # for free, and surviving plans keep intermediates proportional to
        # the (small) delta instead of to the store — the incremental win
        if delta.shape[0] == 0 or not _const_filter(body[i], delta).any():
            continue
        b = Bindings.empty_universe()
        dead = False
        for j in [i, *(j for j in range(len(body)) if j != i)]:
            if j < i:
                src = t_old
            elif j == i:
                src = delta
            else:
                src = t_all
            b, n_cand = join_atom(b, body[j], src)
            if j == i:
                n_appl += n_cand
            if b.nrows == 0:
                dead = True
                break
        if dead:
            continue
        h = instantiate_head(rule.head, b)
        n_deriv += h.shape[0]
        heads.append(h)
    if heads:
        out = np.concatenate(heads, axis=0)
    else:
        out = np.zeros((0, 3), dtype=np.int32)
    return out, n_deriv, n_appl


def eval_rule_full(rule: Rule, t_all: np.ndarray) -> tuple[np.ndarray, int, int]:
    """Full evaluation of a rule against the current store (the R-queue step:
    a rewritten rule must be re-applied to all facts, paper Algorithm 2)."""
    empty = np.zeros((0, 3), dtype=np.int32)
    return eval_rule_delta(rule, empty, t_all, t_all)
