"""Mixture-of-Experts FFN with sort-based, shard-local dispatch (EP x DP).

Top-k routing with softmax-renormalised gates.  Dispatch is the production
bottleneck: the naive one-hot scatter formulation materialises
O(T x E x cap) index tensors — 161 GiB/device replicated for the 235B config
at 4k x 256 (measured by the dry-run).  Instead we dispatch per token-chunk
(one chunk per data shard) with an argsort over expert assignments:

  1. tokens are viewed as (C, T_loc) chunks, C = number of data shards; each
     chunk sorts its (T_loc x K) expert assignments (stable, so token order
     within an expert is preserved),
  2. position-in-expert comes from a binary search of segment starts
     (``searchsorted``) — O(T_loc log T_loc), no (T,E) one-hots,
  3. dispatch/combine are chunk-LOCAL gathers into a (C, E, cap, D) buffer
     sharded (data, model, -, -): the token chunk lives on its data row and
     is replicated across the model axis, so the gather never crosses
     shards; the expert GEMM contracts D with both E (model) and C (data)
     sharded — fully local,
  4. the only EP collective is the combine gather's all-gather of the
     expert outputs across the model axis (the top-k slots a chunk reads
     back) — visible in the dry-run as the per-layer EP boundary.

Per-chunk capacity = T_loc * K * capacity_factor / E (dropless up to the
factor); overflowing (token, k) pairs are dropped, exactly like the
capacity-based GShard/Switch dispatch.  Shared experts (DeepSeekMoE) run
densely in the caller.

With ``n_token_shards=1`` (tests, single device) the same code runs
unchunked and needs no mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import swiglu


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_ffn(
    x: jnp.ndarray,  # (B, S, D)
    router_w: jnp.ndarray,  # (D, E)
    w_gate: jnp.ndarray,  # (E, D, F)
    w_in: jnp.ndarray,  # (E, D, F)
    w_out: jnp.ndarray,  # (E, F, D)
    top_k: int,
    capacity_factor: float = 1.25,
    n_token_shards: int = 1,
    dp_axes: tuple = (),
    ep_axis: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balancing loss)."""
    b, s, d = x.shape
    e = router_w.shape[1]
    n_tok = b * s
    c = max(1, min(n_token_shards, n_tok))
    while n_tok % c:
        c -= 1
    tl = n_tok // c
    tk = tl * top_k
    cap = _round_up(max(8, int(round(tl * top_k * capacity_factor / e))), 8)
    cap = min(cap, tl)

    def cons(v, *spec):
        if ep_axis is None:
            return v
        return jax.lax.with_sharding_constraint(v, P(*spec))

    dp = dp_axes if dp_axes else None

    xt = cons(x.reshape(c, tl, d), dp, None, None)
    logits = jnp.einsum(
        "ctd,de->cte", xt.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (C, Tl, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): E * sum_e f_e * p_e  (global over all chunks)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((c, e), jnp.float32)
    ce = jax.vmap(lambda z, i: z.at[i].add(1.0))(ce, gate_idx.reshape(c, tk))
    aux = e * jnp.sum(me * ce.sum(0) / (n_tok * top_k))

    # --- sort-based dispatch (per chunk) ---
    flat_e = gate_idx.reshape(c, tk).astype(jnp.int32)
    order = jnp.argsort(flat_e, axis=1, stable=True).astype(jnp.int32)  # (C, TK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # segment starts via binary search; position of slot j inside its expert
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32), side="left")
    )(sorted_e).astype(jnp.int32)  # (C, E)
    pos = jnp.arange(tk, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=1
    )
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, e * cap)  # (C, TK); e*cap = drop
    tok = order // top_k  # (C, TK) token index within chunk

    # slot -> (token, gate) maps (sentinel token tl = zero row); chunk-local
    # scatters of small int/f32 arrays
    slot_tok = jnp.full((c, e * cap + 1), tl, jnp.int32)
    slot_tok = jax.vmap(lambda st, sl, tk_: st.at[sl].set(tk_))(slot_tok, slot, tok)
    slot_tok = slot_tok[:, : e * cap]
    sorted_gate = jnp.take_along_axis(
        gate_vals.reshape(c, tk), order, axis=1
    )  # (C, TK) gate value of each sorted (token,k) pair
    slot_gate = jnp.zeros((c, e * cap + 1), jnp.float32)
    slot_gate = jax.vmap(lambda sg, sl, gv: sg.at[sl].set(gv))(
        slot_gate, slot, sorted_gate
    )
    slot_gate = slot_gate[:, : e * cap]

    # --- dispatch: chunk-local gather into (C, E, cap, D) ---
    xt_pad = jnp.concatenate([xt, jnp.zeros((c, 1, d), xt.dtype)], axis=1)
    buf = jnp.take_along_axis(xt_pad, slot_tok[..., None], axis=1)  # (C, E*cap, D)
    buf = cons(buf.reshape(c, e, cap, d), dp, ep_axis, None, None)

    # --- expert GEMM: E (model) x C (data) sharded, contraction local ---
    h = jax.vmap(swiglu, in_axes=(1, 0, 0, 0), out_axes=1)(buf, w_gate, w_in, w_out)
    h = cons(h, dp, ep_axis, None, None)  # (C, E, cap, D)

    # --- combine: gate-weighted SCATTER-ADD of slot contributions ---
    # A token-side gather materialises a dense (C,TK,D) tensor and GSPMD
    # all-reduces it un-contracted (8 GiB f32/layer on 235B, measured).  The
    # scatter-add accumulates into the (C,Tl,D) output directly, so the
    # cross-model-shard combine is an all-reduce of the small output only.
    # Accumulate in the activation dtype: per-shard partials are summed
    # locally (<= top_k adds per token), and the cross-model-shard combine
    # all-reduce then moves bf16 instead of f32 — half the wire bytes
    # (§Perf qwen3 H2b; an SP-layout constraint here was REFUTED: GSPMD
    # kept the f32 all-reduce and added a 3% all-to-all on top).
    h_flat = h.reshape(c, e * cap, d)
    contrib = h_flat * slot_gate[..., None].astype(h_flat.dtype)
    out = jnp.zeros((c, tl + 1, d), x.dtype)
    out = jax.vmap(lambda o, st, cb: o.at[st].add(cb, mode="drop"))(
        out, slot_tok, contrib.astype(x.dtype)
    )
    # slice the sentinel row BEFORE the sharding constraint: the combine
    # all-reduce otherwise carries (Tl+1) rows (measured f32[1,65537,4096]
    # on 235B — the sentinel crossed the wire 94 times per step)
    out = cons(out[:, :tl, :], dp, None, None)
    return out.reshape(b, s, d), aux
