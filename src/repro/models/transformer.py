"""Decoder-only LM (dense or MoE) with scan-over-layers + remat.

Covers the five assigned LM architectures: GQA (+ optional QKV bias), RoPE,
SwiGLU dense FFN or DeepSeek/Qwen-style MoE (optional shared experts),
tied embeddings.  Forward paths:

  * ``loss_fn``     — training loss over (tokens, labels),
  * ``prefill``     — full-sequence forward building a KV cache,
  * ``decode_step`` — one new token against a static-size KV cache.

Sharding: ``param_shardings`` / ``act_constraint`` produce NamedShardings for
the production mesh: batch over (pod, data); heads / ffn / experts / vocab
over model (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .layers import DTYPE, apply_rope, gqa_attention, rms_norm, rope_angles, swiglu
from .moe import moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # MoE (0 experts = dense)
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    attn_chunk: int = 1024
    attn_impl: str = "xla_chunked"  # "flash" = Pallas kernel (TPU serving)
    remat: bool = True
    # scan layers in groups of `remat_group` with one checkpoint per group:
    # the saved residual stack shrinks by the group factor, backward
    # recomputes the group (sqrt-L style memory/compute trade)
    remat_group: int = 1
    # MoE dispatch sharding (set by the launcher; defaults run un-meshed)
    n_token_shards: int = 1
    dp_axes: tuple = ()
    ep_axis: str | None = None
    # FSDP: additionally shard params over the data axes (needed when
    # params/TP > HBM, e.g. 235B bf16 at TP16 = 29 GiB/chip); GSPMD
    # all-gathers each layer's weights inside the scan step
    fsdp: bool = False

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv * self.d_head
        attn += self.n_heads * self.d_head * d
        if self.is_moe:
            ffn = 3 * d * self.d_expert * (self.n_experts + self.n_shared)
            ffn += d * self.n_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        return l * (attn + ffn + 2 * d) + self.vocab * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv * self.d_head
        attn += self.n_heads * self.d_head * d
        ffn = 3 * d * self.d_expert * (self.top_k + self.n_shared) + d * self.n_experts
        return l * (attn + ffn + 2 * d) + self.vocab * d + d


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_params(rng, cfg: LMConfig) -> dict:
    k_embed, k_layers = jax.random.split(rng)

    def norm(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32) * (fan_in**-0.5)).astype(DTYPE)

    d, l = cfg.d_model, cfg.n_layers
    hq, hkv = cfg.n_heads * cfg.d_head, cfg.n_kv * cfg.d_head
    ks = jax.random.split(k_layers, 12)
    layer = {
        "attn_norm": jnp.ones((l, d), jnp.float32),
        "wq": norm(ks[0], (l, d, hq), d),
        "wk": norm(ks[1], (l, d, hkv), d),
        "wv": norm(ks[2], (l, d, hkv), d),
        "wo": norm(ks[3], (l, hq, d), hq),
        "ffn_norm": jnp.ones((l, d), jnp.float32),
    }
    if cfg.qkv_bias:
        layer["bq"] = jnp.zeros((l, hq), DTYPE)
        layer["bk"] = jnp.zeros((l, hkv), DTYPE)
        layer["bv"] = jnp.zeros((l, hkv), DTYPE)
    if cfg.is_moe:
        fe = cfg.d_expert
        layer["router"] = jnp.zeros((l, d, cfg.n_experts), jnp.float32)
        layer["e_gate"] = norm(ks[4], (l, cfg.n_experts, d, fe), d)
        layer["e_in"] = norm(ks[5], (l, cfg.n_experts, d, fe), d)
        layer["e_out"] = norm(ks[6], (l, cfg.n_experts, fe, d), fe)
        if cfg.n_shared:
            fs = fe * cfg.n_shared
            layer["s_gate"] = norm(ks[7], (l, d, fs), d)
            layer["s_in"] = norm(ks[8], (l, d, fs), d)
            layer["s_out"] = norm(ks[9], (l, fs, d), fs)
    else:
        layer["w_gate"] = norm(ks[4], (l, d, cfg.d_ff), d)
        layer["w_in"] = norm(ks[5], (l, d, cfg.d_ff), d)
        layer["w_out"] = norm(ks[6], (l, cfg.d_ff, d), cfg.d_ff)
    return {
        "embed": norm(k_embed, (cfg.vocab, d), d),
        "final_norm": jnp.ones((d,), jnp.float32),
        "layers": layer,
    }


def param_shardings(cfg: LMConfig, mesh, dp=("pod", "data"), tp="model") -> dict:
    """NamedSharding pytree matching ``init_params`` (ZeRO-1 handled by the
    optimizer, which further shards its states over dp).  With ``cfg.fsdp``
    the big per-layer tensors are additionally sharded over dp on a free
    dimension (weights are all-gathered per scan step)."""
    dp = tuple(a for a in dp if a in mesh.axis_names)

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    layer = {
        "attn_norm": ns(None, None),
        "wq": ns(None, None, tp),
        "wk": ns(None, None, tp),
        "wv": ns(None, None, tp),
        "wo": ns(None, tp, None),
        "ffn_norm": ns(None, None),
    }
    if cfg.qkv_bias:
        layer["bq"] = ns(None, tp)
        layer["bk"] = ns(None, tp)
        layer["bv"] = ns(None, tp)
    if cfg.is_moe:
        layer["router"] = ns(None, None, None)
        layer["e_gate"] = ns(None, tp, None, None)
        layer["e_in"] = ns(None, tp, None, None)
        layer["e_out"] = ns(None, tp, None, None)
        if cfg.n_shared:
            layer["s_gate"] = ns(None, None, tp)
            layer["s_in"] = ns(None, None, tp)
            layer["s_out"] = ns(None, tp, None)
    else:
        layer["w_gate"] = ns(None, None, tp)
        layer["w_in"] = ns(None, None, tp)
        layer["w_out"] = ns(None, tp, None)
    out = {
        "embed": ns(tp, None),  # vocab-parallel
        "final_norm": ns(None),
        "layers": layer,
    }
    if cfg.fsdp and dp:
        from repro.optim.adamw import _zero1_sharding  # same free-dim logic

        shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
        out = jax.tree.map(
            lambda s, sh: _zero1_sharding(s, sh.shape, mesh, dp), out, shapes
        )
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer(cfg: LMConfig, x, lp, cos, sin, q_offset, k_cache=None, v_cache=None):
    """One decoder block.  If k_cache/v_cache given (B,T,KV,Dh), the new K/V
    are written into the cache at ``q_offset`` first and attention runs over
    the whole (masked) cache; returns (x', aux, (k_out, v_out)) where k_out is
    the updated cache (or the fresh K/V when no cache)."""
    b, s, d = x.shape
    h = rms_norm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(h.dtype)
        k = k + lp["bk"].astype(h.dtype)
        v = v + lp["bv"].astype(h.dtype)
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv, cfg.d_head)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if k_cache is not None:
        q_off = jnp.asarray(q_offset)
        if q_off.ndim >= 1:  # per-slot cache positions (continuous batching)
            pos = q_off.reshape(b)
            upd = jax.vmap(
                lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0))
            )
            k_cache = upd(k_cache, k.astype(k_cache.dtype), pos)
            v_cache = upd(v_cache, v.astype(v_cache.dtype), pos)
        else:
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, q_offset, 0, 0)
            )
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, q_offset, 0, 0)
            )
        k, v = k_cache.astype(k.dtype), v_cache.astype(v.dtype)
        k_new, v_new = k_cache, v_cache
    else:
        k_new, v_new = k, v
    attn = gqa_attention(
        q, k, v, causal=True, q_offset=q_offset, chunk=cfg.attn_chunk,
        impl=cfg.attn_impl,
    )
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, s, -1), lp["wo"].astype(x.dtype))

    h = rms_norm(x, lp["ffn_norm"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        out, aux = moe_ffn(
            h, lp["router"], lp["e_gate"], lp["e_in"], lp["e_out"],
            cfg.top_k, cfg.capacity_factor,
            n_token_shards=cfg.n_token_shards,
            dp_axes=cfg.dp_axes, ep_axis=cfg.ep_axis,
        )
        if cfg.n_shared:
            out = out + swiglu(h, lp["s_gate"], lp["s_in"], lp["s_out"])
    else:
        out = swiglu(h, lp["w_gate"], lp["w_in"], lp["w_out"])
    return x + out, aux, (k_new, v_new)


def forward(params, cfg: LMConfig, tokens: jnp.ndarray, dp_sharding=None):
    """tokens (B, S) -> hidden (B, S, D), aux loss sum."""
    b, s = tokens.shape
    x = params["embed"].astype(DTYPE)[tokens]
    if dp_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, dp_sharding)
    cos, sin = rope_angles(jnp.arange(s), cfg.d_head, cfg.rope_theta)

    def body(x, lp):
        out, aux, _ = _layer(cfg, x, lp, cos, sin, q_offset=0)
        if dp_sharding is not None:
            out = jax.lax.with_sharding_constraint(out, dp_sharding)
        return out, aux

    g = cfg.remat_group
    if g > 1 and cfg.n_layers % g == 0:
        def group(x, lps):
            x, auxs = jax.lax.scan(body, x, lps)
            return x, auxs.sum()

        if cfg.remat:
            group = jax.checkpoint(group)
        stacked = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers // g, g, *a.shape[1:]),
            params["layers"],
        )
        x, auxs = jax.lax.scan(group, x, stacked)
    else:
        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"])
    return x, auxs.sum()


def logits_of(params, hidden):
    return jnp.einsum("bsd,vd->bsv", hidden, params["embed"].astype(hidden.dtype))


def loss_fn(params, cfg: LMConfig, tokens, labels, dp_sharding=None,
            logits_sharding=None):
    hidden, aux = forward(params, cfg, tokens, dp_sharding)
    logits = logits_of(params, hidden).astype(jnp.float32)
    if logits_sharding is not None:
        # vocab-parallel CE layout: (batch->dp, seq gathered, vocab->model);
        # without it GSPMD keeps seq sharded and replicates the vocab axis,
        # materialising (B,S,V) iota/onehot buffers (2.3 GiB each on 235B)
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    # Vocab-parallel-safe cross entropy: every reduction below runs over the
    # (possibly model-sharded) vocab axis, so GSPMD lowers to local partial
    # reductions + an all-reduce of (B,S) scalars.  A take_along_axis /
    # log_softmax formulation instead all-gathers the full (B,S,V) f32 logits
    # (42 GiB/device at 4k x 256 on smollm — found by the dry-run).
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.exp(logits - m).sum(axis=-1)) + m[..., 0]
    onehot = (labels[..., None] == jnp.arange(cfg.vocab)[None, None, :])
    label_logit = jnp.where(onehot, logits, 0.0).sum(axis=-1)
    nll = lse - label_logit
    return nll.mean() + 0.01 * aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, DTYPE), "v": jnp.zeros(shape, DTYPE)}


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, dp_sharding=None):
    """Full forward that also returns the per-layer KV cache (B,S,..)."""
    b, s = tokens.shape
    x = params["embed"].astype(DTYPE)[tokens]
    if dp_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, dp_sharding)
    cos, sin = rope_angles(jnp.arange(s), cfg.d_head, cfg.rope_theta)

    def body(x, lp):
        out, _, (k, v) = _layer(cfg, x, lp, cos, sin, q_offset=0)
        return out, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_of(params, hidden[:, -1:, :])
    return logits, {"k": ks, "v": vs}


def decode_step(params, cfg: LMConfig, cache: dict, token: jnp.ndarray, pos):
    """One decode step: token (B,), pos scalar int32 (current length).

    The cache has static length T; entries at >= pos are masked by RoPE-side
    causality (q_offset = pos).  Returns (logits (B,V), new cache).
    """
    b = token.shape[0]
    x = params["embed"].astype(DTYPE)[token][:, None, :]  # (B,1,D)
    cos, sin = rope_angles(jnp.asarray(pos)[None], cfg.d_head, cfg.rope_theta)

    def body(x, scanned):
        lp, kc, vc = scanned
        out, _, (kc, vc) = _layer(
            cfg, x, lp, cos, sin, q_offset=pos, k_cache=kc, v_cache=vc
        )
        return out, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_of(params, hidden)[:, 0, :]
    return logits, {"k": ks, "v": vs}
