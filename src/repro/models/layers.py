"""Transformer building blocks: RMSNorm, RoPE, GQA attention (chunked
online-softmax), SwiGLU.  Pure functions over parameter pytrees; bf16
activations / f32 norm accumulations throughout.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

DTYPE = jnp.bfloat16


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def rope_angles(positions: jnp.ndarray, d_head: int, theta: float = 1e6):
    """positions (...,) -> (cos, sin) each (..., d_head//2), f32."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., n_heads, d_head); cos/sin broadcastable to (..., 1, d_head//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray):
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_out.astype(x.dtype))


def _attn_chunk(q, k, v, mask_fn, q_off, k_off):
    """One (q-block x kv-chunk) score/PV step in f32; q (B,Sq,KV,G,D)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / (q.shape[-1] ** 0.5)
    mask = mask_fn(q_off, k_off, scores.shape[-2], scores.shape[-1])
    return jnp.where(mask, scores, -1e30)


def gqa_attention(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, T, KV, Dh)
    v: jnp.ndarray,  # (B, T, KV, Dh)
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    chunk: int = 1024,
    impl: str = "xla_chunked",
) -> jnp.ndarray:
    """Chunked online-softmax GQA attention (flash-style, pure XLA).

    Scans over KV chunks with running (max, denom, acc) so the full (S x T)
    score matrix never materialises — the memory-roofline win for 32k prefill
    (DESIGN.md §6).  Exact: matches naive softmax attention to f32 rounding.

    ``impl="flash"`` dispatches to the Pallas TPU kernel
    (:mod:`repro.kernels.flash_attention`) — identical math, but the score
    blocks live in VMEM scratch instead of HBM (the dominant memory-roofline
    term of the LM cells).  The XLA path stays the CPU/dry-run default and
    the autodiff path (the kernel is fwd-only; training wraps it in the
    chunk-level remat below).
    """
    if impl == "flash" and jnp.asarray(q_offset).ndim == 0:
        from repro.kernels import ops as kops

        return kops.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            block_q=min(128, q.shape[1]), block_k=min(128, k.shape[1]),
        )
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)

    n_chunks = max(1, (t + chunk - 1) // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv, dh).transpose(1, 0, 2, 3, 4)

    def scan_body(carry, inputs):
        m, l, acc = carry
        kb, vb, c_idx = inputs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kb).astype(jnp.float32)
        scores = scores / (dh**0.5)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        valid = k_pos < t
        if causal:
            q_off = jnp.asarray(q_offset)
            if q_off.ndim == 1:  # per-slot positions (continuous batching)
                q_pos = q_off[:, None] + jnp.arange(s)  # (B, S)
                cm = q_pos[:, :, None] >= k_pos[None, None, :]  # (B, S, chunk)
                mask = valid[None, None, :] & cm
                scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
            else:
                q_pos = q_off + jnp.arange(s)
                cm = q_pos[:, None] >= k_pos[None, :]
                mask = valid[None, :] & cm
                scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
        else:
            scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkd->bskgd", p.astype(qg.dtype), vb).astype(jnp.float32)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, s, kv, g, dh), jnp.float32)
    # Checkpoint the chunk body: the backward pass recomputes the (B,H,S,chunk)
    # score/prob blocks per chunk instead of saving them as scan residuals —
    # flash-attention memory semantics (42 GiB -> sub-GiB residuals at 4k).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(scan_body), (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l.transpose(0, 3, 1, 2)[..., None], 1e-30)
    return out.reshape(b, s, h, dh).astype(q.dtype)


def naive_attention(q, k, v, causal=True, q_offset=0):
    """Reference quadratic attention (oracle for the chunked version)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / (dh**0.5)
    if causal:
        q_pos = q_offset + jnp.arange(s)
        mask = q_pos[:, None] >= jnp.arange(t)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v)
    return out.reshape(b, s, h, dh)
