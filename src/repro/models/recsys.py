"""Factorisation Machine (Rendle, ICDM'10) with huge sharded embedding tables.

The hot path is the embedding LOOKUP: JAX has no EmbeddingBag, so lookups are
``jnp.take`` over a row-sharded table (per-field offsets into one arena) and
the pairwise interaction uses the O(F*K) sum-square trick — served by the
fused Pallas kernel :mod:`repro.kernels.fm_interact` on the forward path.

owl:sameAs integration (DESIGN.md §4): an optional ``rho`` row-remap unifies
equivalent IDs (merged user/item registrations) before lookup — one extra
gather, after which merged IDs share one embedding row and its gradients.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_fields: int = 39
    embed_dim: int = 10
    rows_per_field: int = 865_707  # ~33.8M total rows, Criteo-scale
    use_pallas: bool = False  # pure-jnp interaction by default (autodiff path)

    @property
    def n_rows(self) -> int:
        # padded to a multiple of 2048 so the row-sharded table divides the
        # model axis on any production mesh (16-way TP x any pod count)
        raw = self.n_fields * self.rows_per_field
        return (raw + 2047) // 2048 * 2048

    def param_count(self) -> int:
        return self.n_rows * (self.embed_dim + 1) + 1


def init_params(rng, cfg: FMConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "table": jax.random.normal(k1, (cfg.n_rows, cfg.embed_dim), jnp.float32) * 0.01,
        "w1": jnp.zeros((cfg.n_rows,), jnp.float32),
        "bias": jnp.zeros((), jnp.float32),
    }


def param_shardings(cfg: FMConfig, mesh, tp="model") -> dict:
    return {
        "table": NamedSharding(mesh, P(tp, None)),  # row-sharded arena
        "w1": NamedSharding(mesh, P(tp)),
        "bias": NamedSharding(mesh, P()),
    }


def _row_ids(cfg: FMConfig, ids: jnp.ndarray) -> jnp.ndarray:
    offsets = jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.rows_per_field
    return ids + offsets[None, :]


def forward(params, cfg: FMConfig, batch: dict) -> jnp.ndarray:
    """batch: ids (B, F) int32 per-field categorical IDs; optional rho row
    remap (n_rows,) from the sameAs engine.  Returns logits (B,)."""
    rows = _row_ids(cfg, batch["ids"])
    rho = batch.get("rho")
    if rho is not None:
        rows = rho[rows]  # ID unification via the representative map
    emb = jnp.take(params["table"], rows, axis=0)  # (B, F, K)
    if cfg.use_pallas:
        second = kops.fm_interact(emb)
    else:
        s = emb.sum(axis=1)
        second = 0.5 * ((s * s) - (emb * emb).sum(axis=1)).sum(axis=-1)
    first = jnp.take(params["w1"], rows, axis=0).sum(axis=1)
    return params["bias"] + first + second


def loss_fn(params, cfg: FMConfig, batch: dict):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def serve_step(params, cfg: FMConfig, batch: dict) -> jnp.ndarray:
    return jax.nn.sigmoid(forward(params, cfg, batch))


def retrieval_scores(params, cfg: FMConfig, user_ids: jnp.ndarray, cand_rows: jnp.ndarray):
    """Score one user's field-bag embedding against N candidate rows:
    batched dot, not a loop (the ``retrieval_cand`` shape)."""
    rows = _row_ids(cfg, user_ids)  # (1, F)
    q = jnp.take(params["table"], rows[0], axis=0).sum(axis=0)  # (K,)
    cand = jnp.take(params["table"], cand_rows, axis=0)  # (N, K)
    return cand @ q + jnp.take(params["w1"], cand_rows, axis=0)
