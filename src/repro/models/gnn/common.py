"""Shared GNN machinery: segment-op message passing over edge indices.

JAX sparse is BCOO-only, so message passing is built on
``jax.ops.segment_sum``-family reductions over an (2, E) edge index — this IS
part of the system per the assignment.  The Pallas ``segment_sum`` kernel
(:mod:`repro.kernels.segment_sum`) is the TPU hot-path for the sum case.

Edge-parallel distribution: edges are sharded over the data axes; segment
reductions into replicated node states lower to local partial sums + psum
under SPMD (DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def seg_sum(x, seg, n):
    return jax.ops.segment_sum(x, seg, num_segments=n)


def seg_mean(x, seg, n, eps=1e-6):
    s = seg_sum(x, seg, n)
    d = seg_sum(jnp.ones((x.shape[0], 1), x.dtype), seg, n)
    return s / (d + eps)


def _mask_empty(agg, seg, n):
    """Zero out segments with no contributing edges (identity is +-inf)."""
    cnt = seg_sum(jnp.ones((seg.shape[0], 1), agg.dtype), seg, n)
    return jnp.where(cnt > 0, agg, 0.0)


def seg_max(x, seg, n):
    out = jax.ops.segment_max(x, seg, num_segments=n, indices_are_sorted=False)
    return _mask_empty(out, seg, n)


def seg_min(x, seg, n):
    out = jax.ops.segment_min(x, seg, num_segments=n, indices_are_sorted=False)
    return _mask_empty(out, seg, n)


def seg_std(x, seg, n, eps=1e-6):
    m = seg_mean(x, seg, n)
    m2 = seg_mean(x * x, seg, n)
    return jnp.sqrt(jnp.maximum(m2 - m[..., :] ** 2, 0.0) + eps)


def seg_softmax(logits, seg, n):
    """Edge softmax grouped by destination node."""
    mx = seg_max(logits, seg, n)
    ex = jnp.exp(logits - mx[seg])
    den = seg_sum(ex, seg, n)
    return ex / (den[seg] + 1e-9)


def degrees(dst, n):
    return seg_sum(jnp.ones((dst.shape[0], 1), jnp.float32), dst, n)[:, 0]


def mlp(params: list, x, act=jax.nn.silu):
    for i, (w, b) in enumerate(params):
        x = x @ w.astype(x.dtype) + b.astype(x.dtype)
        if i < len(params) - 1:
            x = act(x)
    return x


def init_mlp(rng, dims, dtype=jnp.float32):
    out = []
    keys = jax.random.split(rng, len(dims) - 1)
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        out.append(
            (
                jax.random.normal(k, (a, b), jnp.float32).astype(dtype) * (a**-0.5),
                jnp.zeros((b,), dtype),
            )
        )
    return out


def layer_norm(x, eps=1e-5):
    xf = x.astype(jnp.float32)
    m = xf.mean(-1, keepdims=True)
    v = xf.var(-1, keepdims=True)
    return ((xf - m) * jax.lax.rsqrt(v + eps)).astype(x.dtype)
