"""EGNN — E(n)-equivariant GNN (Satorras et al., arXiv:2102.09844).

    m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
    x_i' += C * sum_j (x_i - x_j) * phi_x(m_ij)
    h_i'  = phi_h(h_i, sum_j m_ij)

Equivariance comes from using only squared distances and relative vectors.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import init_mlp, mlp, seg_sum


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    n_targets: int = 1


def init_params(rng, cfg: EGNNConfig) -> dict:
    ks = jax.random.split(rng, 3 + cfg.n_layers)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + i], 3)
        layers.append(
            {
                "phi_e": init_mlp(lk[0], [2 * h + 1, h, h]),
                "phi_x": init_mlp(lk[1], [h, h, 1]),
                "phi_h": init_mlp(lk[2], [2 * h, h, h]),
            }
        )
    return {
        "embed": init_mlp(ks[0], [cfg.d_in, h]),
        "layers": layers,
        "head": init_mlp(ks[1], [h, h, cfg.n_targets]),
    }


def forward(params, cfg: EGNNConfig, batch: dict):
    """Returns (per-graph prediction, final positions)."""
    h = mlp(params["embed"], batch["x"])
    pos = batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_index"][0], batch["edge_index"][1]
    n = h.shape[0]
    for lp in params["layers"]:
        rel = pos[dst] - pos[src]
        d2 = (rel * rel).sum(-1, keepdims=True)
        m = mlp(lp["phi_e"], jnp.concatenate([h[dst], h[src], d2.astype(h.dtype)], -1))
        w = mlp(lp["phi_x"], m).astype(jnp.float32)
        pos = pos + seg_sum(rel * w, dst, n) / (n**0.5)
        agg = seg_sum(m, dst, n)
        h = h + mlp(lp["phi_h"], jnp.concatenate([h, agg], -1))
    node_out = mlp(params["head"], h)
    gid = batch["graph_ids"]
    n_graphs = batch["n_graphs"]
    pred = seg_sum(node_out, gid, n_graphs)
    return pred, pos


def loss_fn(params, cfg: EGNNConfig, batch: dict):
    pred, _ = forward(params, cfg, batch)
    err = pred[:, 0].astype(jnp.float32) - batch["y"].astype(jnp.float32)
    return (err * err).mean()
