"""DimeNet — directional message passing (Gasteiger et al., arXiv:2003.03123).

Messages live on *directed edges*; the interaction block aggregates over
triplets (k->j->i) with a radial Bessel basis on distances and an angular
basis on the k-j-i angle, combined through an ``n_bilinear`` tensor layer —
the triplet-gather kernel regime of the taxonomy (not expressible as SpMM).

Compact-faithful deviations (documented in DESIGN.md):
  * the angular basis uses cos(l * angle) Chebyshev harmonics x radial Bessel
    instead of full spherical Bessel j_l(z_ln r) x Y_l — same tensor shapes
    (n_spherical x n_radial), same triplet dataflow, simpler special
    functions,
  * output blocks use per-edge MLPs + atom scatter like DimeNet++.

Triplet indices (t_in: edge k->j, t_out: edge j->i) are precomputed by the
data pipeline and are part of the batch (static count).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import init_mlp, mlp, seg_sum


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 16
    envelope_p: int = 6


def radial_bessel(d, n_radial, cutoff, p=6):
    """Bessel RBF with smooth polynomial envelope (DimeNet eq. 7-8)."""
    d = d / cutoff
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    # envelope u(d): 1 + a d^p + b d^(p+1) + c d^(p+2)
    a = -(p + 1) * (p + 2) / 2.0
    b = p * (p + 2)
    c = -p * (p + 1) / 2.0
    env = 1.0 + a * d**p + b * d ** (p + 1) + c * d ** (p + 2)
    env = jnp.where(d < 1.0, env, 0.0)
    return env[:, None] * jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d[:, None]) / jnp.maximum(d[:, None], 1e-6)


def angular_basis(angle, d, n_spherical, n_radial, cutoff):
    """(T, n_spherical * n_radial): cos(l*angle) x radial Bessel of d_kj."""
    rbf = radial_bessel(d, n_radial, cutoff)  # (T, n_radial)
    l = jnp.arange(n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])  # (T, n_spherical)
    return (ang[:, :, None] * rbf[:, None, :]).reshape(angle.shape[0], -1)


def init_params(rng, cfg: DimeNetConfig) -> dict:
    ks = jax.random.split(rng, 6 + cfg.n_blocks)
    h, nb = cfg.d_hidden, cfg.n_bilinear
    nsr = cfg.n_spherical * cfg.n_radial
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[6 + i], 6)
        blocks.append(
            {
                "w_rbf": init_mlp(bk[0], [cfg.n_radial, h])[0][0],
                "w_sbf": init_mlp(bk[1], [nsr, nb])[0][0],
                "w_kj": init_mlp(bk[2], [h, h])[0][0],
                "bilinear": jax.random.normal(bk[3], (h, nb, h), jnp.float32) * (h**-0.5),
                "mlp_out": init_mlp(bk[4], [h, h, h]),
                "out_atom": init_mlp(bk[5], [h, h, 1]),
            }
        )
    return {
        "species_emb": jax.random.normal(ks[0], (cfg.n_species, h), jnp.float32) * 0.1,
        "edge_mlp": init_mlp(ks[1], [2 * h + cfg.n_radial, h, h]),
        "blocks": blocks,
    }


def forward(params, cfg: DimeNetConfig, batch: dict):
    """batch: z (N,) species, pos (N,3), edge_index (2,E) j->i,
    triplets (2,T) = (edge id k->j, edge id j->i), graph_ids, n_graphs."""
    z, pos = batch["z"], batch["pos"].astype(jnp.float32)
    src, dst = batch["edge_index"][0], batch["edge_index"][1]
    t_in, t_out = batch["triplets"][0], batch["triplets"][1]
    n, e = z.shape[0], src.shape[0]

    rel = pos[dst] - pos[src]
    d = jnp.sqrt(jnp.maximum((rel * rel).sum(-1), 1e-12))
    rbf = radial_bessel(d, cfg.n_radial, cfg.cutoff, cfg.envelope_p)

    # triplet angle between edge (k->j) and (j->i): vectors meet at j
    v_kj = -rel[t_in]  # j->k direction flipped: k->j
    v_ji = rel[t_out]
    cosang = (v_kj * v_ji).sum(-1) / jnp.maximum(
        jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-9
    )
    angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = angular_basis(angle, d[t_in], cfg.n_spherical, cfg.n_radial, cfg.cutoff)

    hz = params["species_emb"][z]
    m = mlp(params["edge_mlp"], jnp.concatenate([hz[src], hz[dst], rbf], -1))  # (E, H)

    energy = jnp.zeros((batch["n_graphs"], 1), jnp.float32)
    for blk in params["blocks"]:
        m_rbf = m * (rbf @ blk["w_rbf"])  # (E, H)
        m_kj = (m_rbf @ blk["w_kj"])[t_in]  # (T, H)
        sb = sbf @ blk["w_sbf"]  # (T, nb)
        inter = jnp.einsum("th,tb,hbo->to", m_kj, sb, blk["bilinear"])  # (T, H)
        agg = seg_sum(inter, t_out, e)  # (E, H)
        m = m + mlp(blk["mlp_out"], agg)
        atom = seg_sum(m, dst, n)  # (N, H)
        contrib = mlp(blk["out_atom"], atom)  # (N, 1)
        energy = energy + seg_sum(contrib.astype(jnp.float32), batch["graph_ids"], batch["n_graphs"])
    return energy[:, 0]


def loss_fn(params, cfg: DimeNetConfig, batch: dict):
    pred = forward(params, cfg, batch)
    err = pred - batch["y"].astype(jnp.float32)
    return (err * err).mean()
