"""GatedGCN (Bresson & Laurent, arXiv:1711.07553; benchmark config from
arXiv:2003.00982): edge-gated message passing with residuals.

    e'_ij = A h_i + B h_j + C e_ij
    eta_ij = sigma(e'_ij) / (sum_j' sigma(e'_ij') + eps)
    h'_i  = U h_i + sum_j eta_ij * (V h_j)

LayerNorm replaces the original BatchNorm (batch statistics are hostile to
SPMD sharding; documented deviation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import init_mlp, layer_norm, mlp, seg_sum


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_edge_in: int = 1
    n_classes: int = 40


def init_params(rng, cfg: GatedGCNConfig) -> dict:
    ks = jax.random.split(rng, 4 + cfg.n_layers)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[4 + i], 6)
        layers.append(
            {
                "A": init_mlp(lk[0], [h, h])[0],
                "B": init_mlp(lk[1], [h, h])[0],
                "C": init_mlp(lk[2], [h, h])[0],
                "U": init_mlp(lk[3], [h, h])[0],
                "V": init_mlp(lk[4], [h, h])[0],
            }
        )
    return {
        "embed_x": init_mlp(ks[0], [cfg.d_in, h]),
        "embed_e": init_mlp(ks[1], [cfg.d_edge_in, h]),
        "layers": layers,
        "head": init_mlp(ks[2], [h, h, cfg.n_classes]),
    }


def forward(params, cfg: GatedGCNConfig, batch: dict) -> jnp.ndarray:
    x = mlp(params["embed_x"], batch["x"])
    e = mlp(params["embed_e"], batch["edge_attr"])
    src, dst = batch["edge_index"][0], batch["edge_index"][1]
    n = x.shape[0]
    for lp in params["layers"]:
        (aw, ab), (bw, bb), (cw, cb) = lp["A"], lp["B"], lp["C"]
        (uw, ub), (vw, vb) = lp["U"], lp["V"]
        e_new = x[dst] @ aw + x[src] @ bw + e @ cw + (ab + bb + cb)
        gate = jax.nn.sigmoid(e_new.astype(jnp.float32)).astype(x.dtype)
        msg = gate * (x[src] @ vw + vb)
        den = seg_sum(gate, dst, n) + 1e-6
        agg = seg_sum(msg, dst, n) / den
        x = x + jax.nn.silu(layer_norm(x @ uw + ub + agg))
        e = e + jax.nn.silu(layer_norm(e_new))
    return mlp(params["head"], x)


def loss_fn(params, cfg: GatedGCNConfig, batch: dict):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch.get("train_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
