"""PNA — Principal Neighbourhood Aggregation (arXiv:2004.05718).

Multi-aggregator (mean / max / min / std) x multi-scaler (identity /
amplification / attenuation) message passing with tower MLPs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import degrees, init_mlp, layer_norm, mlp, seg_max, seg_mean, seg_min, seg_std


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_in: int = 1433
    n_classes: int = 40
    delta: float = 2.5  # avg log-degree normaliser from the train graphs


def init_params(rng, cfg: PNAConfig) -> dict:
    ks = jax.random.split(rng, 3 + cfg.n_layers)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + i], 2)
        layers.append(
            {
                "pre": init_mlp(lk[0], [2 * h, h]),  # message MLP on (h_i, h_j)
                "post": init_mlp(lk[1], [12 * h + h, h]),  # 4 agg x 3 scalers + self
            }
        )
    return {
        "embed": init_mlp(ks[0], [cfg.d_in, h]),
        "layers": layers,
        "head": init_mlp(ks[1], [h, h, cfg.n_classes]),
    }


def forward(params, cfg: PNAConfig, batch: dict) -> jnp.ndarray:
    x = mlp(params["embed"], batch["x"])
    src, dst = batch["edge_index"][0], batch["edge_index"][1]
    n = x.shape[0]
    deg = degrees(dst, n)
    log_deg = jnp.log(deg + 1.0)
    amp = (log_deg / cfg.delta)[:, None]
    att = (cfg.delta / jnp.maximum(log_deg, 1e-6))[:, None]
    for lp in params["layers"]:
        m = mlp(lp["pre"], jnp.concatenate([x[dst], x[src]], axis=-1))
        aggs = [
            seg_mean(m, dst, n),
            seg_max(m, dst, n),
            seg_min(m, dst, n),
            seg_std(m, dst, n),
        ]
        agg = jnp.concatenate(aggs, axis=-1)
        scaled = jnp.concatenate([agg, agg * amp, agg * att], axis=-1)
        x = x + jax.nn.silu(layer_norm(mlp(lp["post"], jnp.concatenate([scaled, x], -1))))
    return mlp(params["head"], x)


def loss_fn(params, cfg: PNAConfig, batch: dict):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch.get("train_mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
