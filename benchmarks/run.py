"""Benchmark driver: one section per paper table/figure.

  Table 2 -> bench_materialisation  (AX vs REW work/triples factors)
  Table 3 -> bench_scaling          (wall times across shard counts)
  §5      -> bench_sparql           (query answering on T vs T^rho)
  kernels -> bench_kernels          (Pallas interpret-mode vs jnp oracle)
  updates -> bench_incremental      (host vs sharded maintenance rounds vs
                                     from-scratch; writes BENCH_incremental.json)
  serve   -> bench_serve_updates    (query latency idle vs during maintenance
                                     epochs; writes BENCH_serve.json)

``python -m benchmarks.run [section ...]`` — default: all sections.

``python -m benchmarks.run --check [tolerance]`` — regression gate: rerun
the incremental section (without overwriting the JSON) and exit non-zero if
any dataset regressed against the committed BENCH_incremental.json
baseline — ``speedup_engine_vs_scratch`` (machine-normalised) by more than
``tolerance`` (default 0.2 = 20%), ``steady_engine_s_per_event``
(absolute wall-clock backstop, so a profile with a tiny committed speedup
is still gated against per-event blow-ups) by more than the wider
``max(3 * tolerance, 0.6)``, or ``dispatches_per_event`` (the compiled-call
dispatch floor, machine-INdependent — ROADMAP's fused-fixpoint metric) by
more than ``tolerance``.  Two baseline-independent axes ride along: the
absolute ``DISPATCH_CEILINGS`` and ``full_plan_evals == 0`` on every
profile's maintenance-stream counters (no unconstrained whole-rule
evaluations — exact, deterministic).  The committed BENCH_serve.json rows
are gated too (``compare_serve``: ``busy_over_idle`` and
``batched_speedup`` absolute bounds, clean serve dispatch audits, live
closed-loop epochs) without re-paying the serve bench.  The gate also
reruns the jaxpr trace audit (``repro.analysis``) and fails on any
invariant violation or dispatch cross-check problem.
"""

from __future__ import annotations

import json
import os
import sys
import time

BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_incremental.json",
)

# Absolute per-profile ceilings on steady ``dispatches_per_event`` — the
# machine-independent budget of the fused-fixpoint orchestration.  The
# relative gate above only catches *drift vs the committed baseline*; these
# pin the level itself, so regenerating the baseline on a regressed build
# cannot silently ratify a dispatch blow-up.  Values are the fused steady
# counts (BENCH_incremental.json) with ~2x headroom for stream-shape
# variation (capacity retries, requeued rounds riding the host body);
# the host-loop engine (fuse_rounds=False) sits far above every ceiling.
SERVE_BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)

# Absolute bounds on the committed serving rows (BENCH_serve.json):
#
#   * ``busy_over_idle`` — the epoch-snapshot publication contract: a query
#     admitted between maintenance phases costs what an idle query costs,
#     because the snapshot build (device buffer swap + incremental rho
#     refresh + host mirror) is charged to the barrier, never to the first
#     read.  1.2 leaves room for scheduler noise at ms-scale latencies;
#     the pre-refactor dbpedia_like artifact sat at 1.7.
#   * ``batched_speedup`` — the vmapped shape-grouped drain must beat the
#     scalar host drain by >= 3x on the shape-heavy profile (most triples
#     per predicate, where per-query join overhead dominates).  Other
#     profiles are reported but not pinned: at small served sizes the
#     scalar path is already sub-ms and the ratio is noise.
SERVE_BUSY_OVER_IDLE_MAX = 1.2
SERVE_BATCHED_SPEEDUP_MIN = 3.0
SERVE_SPEEDUP_PROFILES = ("dbpedia_like",)

DISPATCH_CEILINGS: dict[str, float] = {
    "claros_like": 15.0,    # fused steady 7.5
    "dbpedia_like": 17.0,   # fused steady 8.2
    "opencyc_like": 14.0,   # fused steady 7.0
    "uniprot_like": 15.0,   # fused steady 7.5
    "uobm_like": 15.0,      # fused steady 7.0
    "chain_like": 12.0,     # fused steady 6.0 (unfused: 24.0)
    "clique_like": 11.0,    # fused steady 5.5 (unfused: 21.8)
    "merge_like": 40.0,     # fused steady 19.8 — merge-heavy streams pay
                            # one mplan dispatch per rewritten rule per
                            # event on top of the ordinary round budget
}


def compare_incremental(
    rows: list[dict],
    baseline_doc: dict,
    tolerance: float = 0.2,
    time_tolerance: float | None = None,
    dispatch_ceilings: dict | None = None,
) -> list[str]:
    """Regressions vs a committed baseline doc, on two axes per dataset:

      * ``speedup_engine_vs_scratch`` falling more than ``tolerance``
        (fractional, default 20%) below the committed value — the
        machine-normalised gate (scratch time divides out host speed);
      * ``steady_engine_s_per_event`` rising more than ``time_tolerance``
        (default ``max(3 * tolerance, 0.6)`` = 60%) above the committed
        value — an absolute wall-clock backstop.  It catches a profile
        whose committed speedup is so small that speedup noise swamps a
        many-x per-event blow-up (the uobm_like failure mode of PR 4:
        steady 7.30 -> 11.93 s/event, +63%).  Its tolerance is wider than
        the speedup axis because raw engine wall-clock varies ~30-50%
        run-to-run at CPU bench scale (XLA compile/dispatch jitter), and
        it IS machine-dependent — regenerate the baseline on the CI
        machine before trusting a bare time gate;
      * ``dispatches_per_event`` rising more than ``tolerance`` above the
        committed value — the steady compiled-call dispatch count per
        maintenance event (repro.analysis's DispatchAuditor, counted at
        the engine fn cache).  Deterministic for a given rule set and
        update stream — no timing jitter — so it shares the tight speedup
        tolerance; it is the before/after metric of the ROADMAP's
        fused-fixpoint item, and a silent extra dispatch per round is
        exactly what it exists to catch.

    ``dispatch_ceilings`` (profile -> absolute dispatches_per_event bound)
    adds a baseline-INdependent axis: a row whose steady dispatch count
    exceeds its ceiling fails even if the committed baseline is equally
    bad — the relative gate only sees drift, the ceiling pins the level
    (see ``DISPATCH_CEILINGS``).  Profiles without a ceiling are skipped.

    A second baseline-independent axis enforces ``full_plan_evals == 0``
    on every row's ``engine_counters`` (and on the committed baseline's
    rows, so a regenerated JSON cannot ratify a regression): maintenance
    must never fall back to an unconstrained whole-rule evaluation —
    deletes rederive head-bound (rplan), rho re-merges evaluate
    merge-anchored (mplan).  The counter is deterministic, so the
    tolerance is exact zero; a row that carries ``engine_counters`` but
    *not* this counter fails too (a silently dropped counter must not
    read as a pass).  Rows without ``engine_counters`` at all — the
    minimal synthetic rows of the gate's own unit tests — are skipped.

    Datasets missing from either side, or null on the baseline side, are
    skipped per-metric.  Pure so the tier-1 bench smoke can pin the gate's
    semantics without timing anything.
    """
    if time_tolerance is None:
        time_tolerance = max(3 * tolerance, 0.6)
    base = {r["dataset"]: r for r in baseline_doc.get("rows", [])}
    problems = []
    for r in rows:
        b = base.get(r["dataset"])
        if b is None:
            continue
        want = b.get("speedup_engine_vs_scratch")
        got = r.get("speedup_engine_vs_scratch")
        if want is not None and (got is None or got < want * (1.0 - tolerance)):
            problems.append(
                f"{r['dataset']}: speedup_engine_vs_scratch {got} < "
                f"baseline {want} - {int(tolerance * 100)}%"
            )
        want_t = b.get("steady_engine_s_per_event")
        got_t = r.get("steady_engine_s_per_event")
        if want_t is not None and got_t is not None and (
            got_t > want_t * (1.0 + time_tolerance)
        ):
            problems.append(
                f"{r['dataset']}: steady_engine_s_per_event {got_t} > "
                f"baseline {want_t} + {int(time_tolerance * 100)}%"
            )
        want_d = b.get("dispatches_per_event")
        got_d = r.get("dispatches_per_event")
        if want_d is not None and got_d is not None and (
            got_d > want_d * (1.0 + tolerance)
        ):
            problems.append(
                f"{r['dataset']}: dispatches_per_event {got_d} > "
                f"baseline {want_d} + {int(tolerance * 100)}%"
            )
    for r in rows:
        ceil = (dispatch_ceilings or {}).get(r["dataset"])
        got_d = r.get("dispatches_per_event")
        if ceil is not None and got_d is not None and got_d > ceil:
            problems.append(
                f"{r['dataset']}: dispatches_per_event {got_d} > absolute "
                f"ceiling {ceil}"
            )
    for origin, rs in (("run", rows), ("baseline", baseline_doc.get("rows", []))):
        for r in rs:
            counters = r.get("engine_counters")
            if counters is None:
                continue
            fpe = counters.get("full_plan_evals")
            if fpe != 0:
                problems.append(
                    f"{r['dataset']}: {origin} full_plan_evals "
                    f"{'missing' if fpe is None else fpe} != 0 "
                    "(unconstrained whole-rule evaluation on a maintenance "
                    "path)"
                )
    return problems


def compare_serve(
    rows: list[dict],
    busy_over_idle_max: float = SERVE_BUSY_OVER_IDLE_MAX,
    batched_speedup_min: float = SERVE_BATCHED_SPEEDUP_MIN,
    speedup_profiles: tuple[str, ...] = SERVE_SPEEDUP_PROFILES,
) -> list[str]:
    """Validate serving rows against the absolute serving bounds.

    Pure (no benching, no I/O) so the tier-1 tests can pin its semantics;
    ``check()`` feeds it the committed BENCH_serve.json rows — the gate
    validates the committed *claims* rather than re-paying the serve bench:

      * every row's ``busy_over_idle`` must stay ≤ ``busy_over_idle_max``
        (the snapshot-publication attribution contract — reads never pay
        the snapshot build);
      * every ``speedup_profiles`` row's ``batched_speedup`` must reach
        ``batched_speedup_min`` (and the row must exist at all — a dropped
        profile must not read as a pass);
      * any row carrying a non-empty ``audit_problems`` list fails (the
        store's dispatch audit ran dirty when the row was generated);
      * a closed-loop section that submitted updates but completed zero
        epochs during/after the window fails — the threaded worker never
        ran, so the latency numbers measured an idle store.
    """
    problems: list[str] = []
    seen = set()
    for r in rows:
        name = r.get("dataset", "?")
        seen.add(name)
        boi = r.get("busy_over_idle")
        if boi is None or boi > busy_over_idle_max:
            problems.append(
                f"{name}: busy_over_idle {boi} > {busy_over_idle_max} "
                "(busy reads are paying maintenance/snapshot cost)"
            )
        if name in speedup_profiles:
            spd = r.get("batched_speedup")
            if spd is None or spd < batched_speedup_min:
                problems.append(
                    f"{name}: batched_speedup {spd} < {batched_speedup_min}"
                )
        if r.get("audit_problems"):
            problems.append(
                f"{name}: serve dispatch audit dirty: {r['audit_problems']}"
            )
        cl = r.get("closed_loop")
        if cl is not None and cl.get("updates_submitted", 0) > 0 and not (
            cl.get("epochs_completed", 0) > 0
        ):
            problems.append(
                f"{name}: closed_loop completed 0 epochs for "
                f"{cl['updates_submitted']} submitted updates "
                "(worker never ran — latency row measured an idle store)"
            )
    for name in speedup_profiles:
        if name not in seen:
            problems.append(
                f"{name}: missing from serve rows (batched_speedup gate "
                "cannot run)"
            )
    return problems


def check(tolerance: float = 0.2) -> int:
    """Run the incremental bench and gate it against the committed JSON,
    then rerun the jaxpr trace audit — both must be clean."""
    from benchmarks import bench_incremental

    if not os.path.exists(BASELINE):
        print(f"[check] no baseline at {BASELINE}; nothing to gate against")
        return 0
    with open(BASELINE) as fh:
        baseline_doc = json.load(fh)
    rows = bench_incremental.main(out_json=None)
    problems = compare_incremental(
        rows, baseline_doc, tolerance, dispatch_ceilings=DISPATCH_CEILINGS
    )

    if os.path.exists(SERVE_BASELINE):
        with open(SERVE_BASELINE) as fh:
            serve_doc = json.load(fh)
        problems += [
            f"serve: {p}" for p in compare_serve(serve_doc.get("rows", []))
        ]
    else:
        print(f"[check] no serve baseline at {SERVE_BASELINE}; skipping")

    from repro.analysis import run_report

    audit = run_report("pex")
    problems += [
        f"audit: [{v['pass_name']}] {v['fn']}: {v['primitive']} at {v['path']}"
        for v in audit["violations"]
    ]
    problems += [f"audit: {p}" for p in audit["dispatch"]["problems"]]
    if problems:
        print("[check] FAIL: bench regression or trace-audit violation")
        for p in problems:
            print("  -", p)
        return 1
    print(
        f"[check] OK: no dataset regressed >{int(tolerance * 100)}% vs "
        "baseline; trace audit clean"
    )
    return 0


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "--check":
        tol = float(argv[1]) if len(argv) > 1 else 0.2
        raise SystemExit(check(tol))
    sections = argv or [
        "materialisation", "scaling", "sparql", "kernels", "incremental",
        "serve",
    ]
    t0 = time.time()
    if "materialisation" in sections:
        print("=" * 72)
        print("Table 2 analogue: AX vs REW (data/generator.py profiles)")
        print("=" * 72)
        from benchmarks import bench_materialisation

        bench_materialisation.main()
    if "scaling" in sections:
        print("=" * 72)
        print("Table 3 analogue: wall time vs shard count (subprocesses)")
        print("=" * 72)
        from benchmarks import bench_scaling

        bench_scaling.main()
    if "sparql" in sections:
        print("=" * 72)
        print("§5 analogue: SPARQL on rewritten vs expanded triples")
        print("=" * 72)
        from benchmarks import bench_sparql

        bench_sparql.main()
    if "kernels" in sections:
        print("=" * 72)
        print("Pallas kernels (interpret mode) vs jnp oracle")
        print("=" * 72)
        from benchmarks import bench_kernels

        bench_kernels.main()
    if "incremental" in sections:
        print("=" * 72)
        print("Update streams: host vs sharded maintenance vs from-scratch")
        print("=" * 72)
        from benchmarks import bench_incremental

        bench_incremental.main(out_json="BENCH_incremental.json")
    if "serve" in sections:
        print("=" * 72)
        print("Serving: SPARQL latency idle vs during maintenance epochs")
        print("=" * 72)
        from benchmarks import bench_serve_updates

        bench_serve_updates.main(out_json="BENCH_serve.json")
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
