"""Benchmark driver: one section per paper table/figure.

  Table 2 -> bench_materialisation  (AX vs REW work/triples factors)
  Table 3 -> bench_scaling          (wall times across shard counts)
  §5      -> bench_sparql           (query answering on T vs T^rho)
  kernels -> bench_kernels          (Pallas interpret-mode vs jnp oracle)
  updates -> bench_incremental      (host vs sharded maintenance rounds vs
                                     from-scratch; writes BENCH_incremental.json)
  serve   -> bench_serve_updates    (query latency idle vs during maintenance
                                     epochs; writes BENCH_serve.json)

``python -m benchmarks.run [section ...]`` — default: all sections.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    sections = sys.argv[1:] or [
        "materialisation", "scaling", "sparql", "kernels", "incremental",
        "serve",
    ]
    t0 = time.time()
    if "materialisation" in sections:
        print("=" * 72)
        print("Table 2 analogue: AX vs REW (data/generator.py profiles)")
        print("=" * 72)
        from benchmarks import bench_materialisation

        bench_materialisation.main()
    if "scaling" in sections:
        print("=" * 72)
        print("Table 3 analogue: wall time vs shard count (subprocesses)")
        print("=" * 72)
        from benchmarks import bench_scaling

        bench_scaling.main()
    if "sparql" in sections:
        print("=" * 72)
        print("§5 analogue: SPARQL on rewritten vs expanded triples")
        print("=" * 72)
        from benchmarks import bench_sparql

        bench_sparql.main()
    if "kernels" in sections:
        print("=" * 72)
        print("Pallas kernels (interpret mode) vs jnp oracle")
        print("=" * 72)
        from benchmarks import bench_kernels

        bench_kernels.main()
    if "incremental" in sections:
        print("=" * 72)
        print("Update streams: host vs sharded maintenance vs from-scratch")
        print("=" * 72)
        from benchmarks import bench_incremental

        bench_incremental.main(out_json="BENCH_incremental.json")
    if "serve" in sections:
        print("=" * 72)
        print("Serving: SPARQL latency idle vs during maintenance epochs")
        print("=" * 72)
        from benchmarks import bench_serve_updates

        bench_serve_updates.main(out_json="BENCH_serve.json")
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
