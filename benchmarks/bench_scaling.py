"""Table 3 analogue: materialisation wall time, AX vs REW, across shard
counts.

The paper scales threads on one shared-memory node; our SPMD adaptation
scales mesh shards.  This container has ONE physical core, so multi-shard
wall times measure partitioning overhead, not speedup — the honest scaling
signal on real hardware comes from the dry-run collective analysis
(EXPERIMENTS.md §Roofline).  What IS real on CPU and mirrors the paper's
Table 3 is the AX/REW wall-time factor per dataset at each shard count
(every shard count is a subprocess with that many fake devices).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

_SCRIPT = textwrap.dedent(
    """
    import json, sys, time
    import numpy as np, jax
    from repro.data.generator import generate, PROFILES
    from repro.core.materialise import materialise
    from repro.core.engine_jax import JaxEngine

    profile, n_dev = sys.argv[1], int(sys.argv[2])
    facts, prog, dic = generate(**PROFILES[profile])

    t0 = time.time(); materialise(facts, prog, dic.n_resources, mode="AX")
    ax_s = time.time() - t0
    t0 = time.time(); materialise(facts, prog, dic.n_resources, mode="REW")
    rew_np_s = time.time() - t0

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((n_dev,), ("data",))
    cap = 1 << 17
    eng = JaxEngine(dic.n_resources, capacity=cap // n_dev, bind_cap=1 << 14,
                    out_cap=1 << 14, rewrite_cap=1 << 14, mesh=mesh)
    t0 = time.time()
    spo, rep, stats = eng.materialise(facts, prog)
    rew_jax_s = time.time() - t0
    print(json.dumps({
        "profile": profile, "n_dev": n_dev, "ax_s": ax_s,
        "rew_np_s": rew_np_s, "rew_jax_s": rew_jax_s,
        "derivations": int(stats.derivations), "rounds": int(stats.rounds),
    }))
    """
)


def run_cell(profile: str, n_dev: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, profile, str(n_dev)],
        capture_output=True, text=True, env=env, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if out.returncode != 0:
        return {"profile": profile, "n_dev": n_dev, "error": out.stderr[-400:]}
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(profiles=("claros_like", "opencyc_like"), shard_counts=(1, 2, 4)) -> list:
    rows = []
    print("profile        shards   AX(np)   REW(np)  REW(jax)  AX/REW(np)  derivs")
    for profile in profiles:
        for n in shard_counts:
            r = run_cell(profile, n)
            rows.append(r)
            if "error" in r:
                print(f"{profile:14s} {n:6d}   ERROR {r['error'][:80]}")
                continue
            print(
                f"{profile:14s} {n:6d} {r['ax_s']:8.3f} {r['rew_np_s']:8.3f}"
                f" {r['rew_jax_s']:9.3f} {r['ax_s']/max(r['rew_np_s'],1e-9):10.2f}"
                f" {r['derivations']:8d}"
            )
    return rows


if __name__ == "__main__":
    main()
