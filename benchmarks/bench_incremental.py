"""Incremental maintenance vs from-scratch re-materialisation, host vs sharded.

For each dataset profile: materialise once, then apply a sampled update
stream (repro.data.generator.sample_update_stream) three ways —

  * **host**:    ``repro.core.incremental`` add_facts/delete_facts (the PR 1
                 reference subsystem, every maintenance round on the host),
  * **engine**:  the sharded update rounds of ``repro.core.incremental_spmd``
                 through ``JaxEngine.add_facts/delete_facts`` (epoch-tagged
                 tombstones + owner-routed delta exchange; single device
                 here, same code the mesh wraps with shard_map),
  * **scratch**: re-running ``materialise_rew`` from scratch on the updated
                 explicit set after every event.

Oracle equality (same normal-form store + rho after every event, all three
ways) is asserted as the benchmark runs, so the numbers are trustworthy by
construction.  ``steady_*`` means exclude each op kind's first occurrence —
that is where the engine path pays its jit compilation, which a standing
service pays once.  Warm-up events are excluded *consistently*: when every
event is a first occurrence (tiny streams) the steady columns are null
rather than silently averaging compile time in, and each row records
``n_warmup`` so the JSON is self-describing.

Historical caveat, resolved: earlier revisions paid several arena-wide
XLA-CPU ``argsort``s per engine round (~7x slower than numpy's sort at
262k rows), which made the single-core engine path lose wall-clock to the
host path.  The persistent sorted arena index (EngineState.sort_perm /
sorted_keys — merge-on-insert, stable-partition removal, at most one full
argsort per mutation epoch) plus delta-width buffers removed those sorts;
steady per-event engine work now scales with the update's blast radius,
and the remaining mesh argument is unchanged: per-shard work divides with
the device count.

``main(out_json=...)`` (or ``benchmarks/run.py incremental``) writes the rows
to BENCH_incremental.json so the perf trajectory is machine-readable.
"""

from __future__ import annotations

import copy
import json
import time

import numpy as np

from repro.core.engine_jax import JaxEngine
from repro.core.incremental import add_facts, delete_facts, materialise_incremental
from repro.core.materialise import materialise_rew
from repro.core.triples import apply_op as _apply_explicit, pack
from repro.data.generator import PROFILES, generate, sample_update_stream


def _steady_mask(events) -> np.ndarray:
    """False for each op kind's first occurrence (compile warm-up events)."""
    seen: set[str] = set()
    mask = np.ones(len(events), dtype=bool)
    for i, (op, _delta) in enumerate(events):
        if op not in seen:
            seen.add(op)
            mask[i] = False
    return mask


def run_one(
    name: str, kw: dict, n_events: int = 8, batch: int = 24, seed: int = 0
) -> dict:
    facts, program, dic = generate(**kw, seed=seed)
    events = sample_update_stream(
        facts, dic, n_events=n_events, batch=batch, seed=seed
    )

    # host base + engine base
    t0 = time.perf_counter()
    host_state = materialise_incremental(facts, program, dic.n_resources)
    host_base_s = time.perf_counter() - t0

    # padded join/sort cost scales with the caps, so size the arena to the
    # workload (~4x the explicit set for derivations + tombstone churn) and
    # let the engine's targeted retry growth absorb misestimates
    cap = 1 << max(12, int(np.ceil(np.log2(4 * facts.shape[0]))))
    eng = JaxEngine(
        dic.n_resources, capacity=cap, bind_cap=cap // 2,
        out_cap=cap // 2, rewrite_cap=cap // 4, seed_chunk=8192,
    )
    t0 = time.perf_counter()
    eng_state = eng.materialise_state(facts, program)
    eng_base_s = time.perf_counter() - t0
    # counter baseline: engine_counters below report the UPDATE STREAM's
    # deltas, net of the base materialisation (whose whole-rule requeues
    # are the paper's Algorithm 1 semantics and legitimately book
    # full_plan_evals — the maintenance paths must not)
    base_stats = copy.copy(eng_state.stats)

    host_ev, eng_ev, scr_ev, disp_ev = [], [], [], []
    explicit = facts
    for op, delta in events:
        explicit = _apply_explicit(explicit, op, delta)

        t0 = time.perf_counter()
        (add_facts if op == "add" else delete_facts)(host_state, delta)
        host_ev.append(time.perf_counter() - t0)

        d0 = eng.dispatches.total
        t0 = time.perf_counter()
        (eng.add_facts if op == "add" else eng.delete_facts)(eng_state, delta)
        eng_ev.append(time.perf_counter() - t0)
        disp_ev.append(eng.dispatches.total - d0)

        t0 = time.perf_counter()
        ref = materialise_rew(explicit, program, dic.n_resources)
        scr_ev.append(time.perf_counter() - t0)

        want = set(pack(ref.triples()).tolist())
        assert set(pack(host_state.triples()).tolist()) == want, (name, op, "host")
        assert (host_state.rep[: ref.rep.shape[0]] == ref.rep).all(), (name, op)
        assert set(pack(eng.state_triples(eng_state)).tolist()) == want, (
            name, op, "engine",
        )
        assert (eng.state_rep(eng_state)[: ref.rep.shape[0]] == ref.rep).all(), (
            name, op, "engine-rep",
        )

    host_ev, eng_ev, scr_ev = map(np.asarray, (host_ev, eng_ev, scr_ev))
    disp_ev = np.asarray(disp_ev)
    # warm-up (each op kind's first occurrence, where the engine pays jit
    # compilation) is excluded from the steady means CONSISTENTLY: a stream
    # of nothing but first occurrences reports null steady columns instead
    # of silently averaging compile time in and overstating engine cost
    steady = _steady_mask(events)
    n_warmup = int((~steady).sum())

    def mean(x, m=None):
        x = x if m is None else x[m]
        return float(x.mean()) if x.size else None

    def rnd(v, nd=4):
        return None if v is None else round(v, nd)

    def ratio(num, den):
        # 4 decimals: a sub-0.005 speedup must not round to 0.0, which
        # would make the --check regression gate vacuous for that dataset
        if num is None or den is None:
            return None
        return round(num / max(den, 1e-9), 4)

    sh, se, ss = mean(host_ev, steady), mean(eng_ev, steady), mean(scr_ev, steady)
    # steady compiled-call dispatches per event (the ROADMAP dispatch floor
    # the fused-fixpoint work must lower; repro.core.stats.DispatchCounter
    # via the engine fn cache).  Same warm-up exclusion as the time columns:
    # first occurrences also pay the one-off cache fills.
    sd = mean(disp_ev.astype(float), steady)
    est = eng_state.stats
    return {
        "dataset": name,
        "facts": int(facts.shape[0]),
        "events": len(events),
        "n_warmup": n_warmup,
        "host_base_s": round(host_base_s, 3),
        "engine_base_s": round(eng_base_s, 3),
        "host_s_per_event": rnd(mean(host_ev)),
        "engine_s_per_event": rnd(mean(eng_ev)),
        "scratch_s_per_event": rnd(mean(scr_ev)),
        "steady_host_s_per_event": rnd(sh),
        "steady_engine_s_per_event": rnd(se),
        "steady_scratch_s_per_event": rnd(ss),
        "speedup_host_vs_scratch": ratio(ss, sh),
        "speedup_engine_vs_scratch": ratio(ss, se),
        "speedup_engine_vs_host": ratio(sh, se),
        "dispatches_per_event": rnd(sd, 2),
        "dispatch_families": {
            k: int(v) for k, v in sorted(eng.dispatches.by_family.items())
        },
        # engine-path health counters over the update stream (deltas net of
        # the base materialisation): how often the arena index was
        # argsorted, how many mid-op rollback restarts fired (and how many
        # grew a wide cap — the recompile-heavy kind), how the delete-side
        # rederivation behaved (targeted joins vs whole-rule fallbacks,
        # seed cardinality, widest padded seed table), how the forward-side
        # re-merge path behaved on rho rewrites (merge-anchored evals vs
        # ground-atom fallbacks), and how often a delta window overflowed
        # to all-True plan masks.  full_plan_evals == 0 here is the
        # no-unconstrained-evaluation invariant run.py --check enforces.
        "engine_counters": {
            "index_rebuilds": est.index_rebuilds - base_stats.index_rebuilds,
            "capacity_retries": est.capacity_retries - base_stats.capacity_retries,
            "wide_growth_restarts": (
                est.wide_growth_restarts - base_stats.wide_growth_restarts
            ),
            "rederive_targeted": est.rederive_targeted - base_stats.rederive_targeted,
            "rederive_full_fallback": (
                est.rederive_full_fallback - base_stats.rederive_full_fallback
            ),
            "rederive_seed_rows": (
                est.rederive_seed_rows - base_stats.rederive_seed_rows
            ),
            "rederive_join_width": est.rederive_join_width,
            "full_plan_evals": est.full_plan_evals - base_stats.full_plan_evals,
            "rule_rewrites": est.rule_rewrites - base_stats.rule_rewrites,
            "remerge_targeted": est.remerge_targeted - base_stats.remerge_targeted,
            "remerge_full_fallback": (
                est.remerge_full_fallback - base_stats.remerge_full_fallback
            ),
            "delta_mask_fallbacks": (
                est.delta_mask_fallbacks - base_stats.delta_mask_fallbacks
            ),
        },
        "per_event": {
            "ops": [op for op, _ in events],
            "host_s": [round(float(x), 4) for x in host_ev],
            "engine_s": [round(float(x), 4) for x in eng_ev],
            "scratch_s": [round(float(x), 4) for x in scr_ev],
            "dispatches": [int(x) for x in disp_ev],
        },
    }


def main(profiles=None, out_json: str | None = None) -> list[dict]:
    rows = []
    print(
        "dataset           facts  ev  host/ev  engine/ev  scratch/ev"
        "  eng-vs-scr  eng-vs-host   (steady means)"
    )

    def fmt(v, width, nd=4):
        return f"{v:{width}.{nd}f}" if v is not None else " " * (width - 4) + "n/a "

    for name, kw in (profiles or PROFILES).items():
        r = run_one(name, kw)
        print(
            f"{r['dataset']:17s} {r['facts']:6d} {r['events']:3d}"
            f" {fmt(r['steady_host_s_per_event'], 9)}"
            f" {fmt(r['steady_engine_s_per_event'], 10)}"
            f" {fmt(r['steady_scratch_s_per_event'], 11)}"
            f"  x{'n/a' if r['speedup_engine_vs_scratch'] is None else r['speedup_engine_vs_scratch']:<9}"
            f" x{'n/a' if r['speedup_engine_vs_host'] is None else r['speedup_engine_vs_host']}"
        )
        rows.append(r)
    if out_json:
        doc = {
            "caveat": (
                "steady means exclude each op kind's first occurrence "
                "(n_warmup events: jit compilation a standing service pays "
                "once).  The historical '~7x XLA-CPU argsort' caveat is "
                "resolved: the persistent sorted arena index "
                "(EngineState.sort_perm/sorted_keys, merge-on-insert, at "
                "most one full argsort per mutation epoch) plus delta-width "
                "bind/out/rewrite buffers removed the per-round arena "
                "sorts, so single-core per-event wall-clock now scales with "
                "the update's blast radius; on a mesh the same per-shard "
                "work additionally divides with the device count.  The PR 4 "
                "uobm_like regression (store-scale clique-split deletes "
                "paying whole-rule rederivation + wide-buffer width "
                "discovery inside the 8-event window) is resolved by "
                "targeted rederivation: delete-side rederive joins are "
                "head-bound to the overdeleted instances and delta buffers "
                "are pre-sized from the admitted batch/overdelete "
                "cardinality — engine_counters records the per-profile "
                "restart/rederive behaviour"
            ),
            "rows": rows,
        }
        # embed the trace-audit report (jaxpr invariant passes + dispatch
        # cross-check) so the bench JSON carries the full perf contract —
        # run.py --check fails on violations as well as on row regressions
        from repro.analysis import run_report

        doc["audit"] = run_report("pex")
        with open(out_json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"[bench_incremental] wrote {out_json}")
    return rows


if __name__ == "__main__":
    main(out_json="BENCH_incremental.json")
