"""Incremental maintenance vs from-scratch re-materialisation.

For each dataset profile: materialise once, then apply a sampled update
stream (repro.data.generator.sample_update_stream) twice — once through
``repro.core.incremental`` (add_facts/delete_facts on the standing state)
and once by re-running ``materialise_rew`` from scratch on the updated
explicit set after every event.  Reports per-event means and the speedup;
the oracle equality (same normal-form store + rho after every event) is
asserted as the benchmark runs, so the numbers are trustworthy by
construction — the successor paper's (arXiv:1505.00212) headline claim is
exactly that maintenance beats recomputation on small update batches.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.incremental import add_facts, delete_facts, materialise_incremental
from repro.core.materialise import materialise_rew
from repro.core.triples import pack, unpack
from repro.data.generator import PROFILES, generate, sample_update_stream


def _apply_explicit(explicit: np.ndarray, op: str, delta: np.ndarray) -> np.ndarray:
    cur = set(pack(explicit).tolist())
    d = set(pack(delta).tolist())
    cur = (cur | d) if op == "add" else (cur - d)
    keys = np.asarray(sorted(cur), dtype=np.int64)
    return unpack(keys) if keys.shape[0] else np.zeros((0, 3), np.int32)


def run_one(name: str, kw: dict, n_events: int = 8, batch: int = 24, seed: int = 0) -> dict:
    facts, program, dic = generate(**kw, seed=seed)
    events = sample_update_stream(facts, dic, n_events=n_events, batch=batch, seed=seed)

    t0 = time.perf_counter()
    state = materialise_incremental(facts, program, dic.n_resources)
    base_s = time.perf_counter() - t0

    inc_s = scr_s = 0.0
    explicit = facts
    for op, delta in events:
        explicit = _apply_explicit(explicit, op, delta)
        t0 = time.perf_counter()
        (add_facts if op == "add" else delete_facts)(state, delta)
        inc_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        ref = materialise_rew(explicit, program, dic.n_resources)
        scr_s += time.perf_counter() - t0
        assert set(pack(state.triples()).tolist()) == set(pack(ref.triples()).tolist()), (
            name, op
        )
        assert (state.rep[: ref.rep.shape[0]] == ref.rep).all(), (name, op)

    return {
        "dataset": name,
        "facts": int(facts.shape[0]),
        "events": len(events),
        "base_s": round(base_s, 3),
        "incremental_s_per_event": round(inc_s / len(events), 4),
        "scratch_s_per_event": round(scr_s / len(events), 4),
        "speedup": round(scr_s / max(inc_s, 1e-9), 2),
    }


def main(profiles=None) -> list[dict]:
    rows = []
    print(
        "dataset           facts  events  base_s   inc_s/ev  scratch_s/ev  speedup"
    )
    for name, kw in (profiles or PROFILES).items():
        r = run_one(name, kw)
        print(
            f"{r['dataset']:17s} {r['facts']:6d} {r['events']:6d} {r['base_s']:8.3f}"
            f" {r['incremental_s_per_event']:9.4f} {r['scratch_s_per_event']:12.4f}"
            f" x{r['speedup']}"
        )
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
