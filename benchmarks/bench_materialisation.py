"""Table 2 analogue: AX vs REW on the five dataset profiles.

Columns mirror the paper: triples after (unmarked/total), rule applications,
derivations, merged resources, wall time — plus the AX/REW factor row.  The
paper's headline numbers at full scale: triples up to 7.8x, derivations up to
85.5x, time up to 31.1x, and the derivation factor >> triple factor
(rewriting's main win is eliminating duplicate derivations).  The benchmark
asserts the same ORDERING of effects on the reduced profiles.
"""

from __future__ import annotations

import time

from repro.core.materialise import check_theorem1, materialise
from repro.data.generator import PROFILES, generate


def run_one(name: str, kw: dict) -> dict:
    facts, program, dic = generate(**kw)
    out = {"dataset": name, "facts": int(facts.shape[0]), "rules": len(program)}
    results = {}
    for mode in ("AX", "REW"):
        t0 = time.time()
        res = materialise(facts, program, dic.n_resources, mode=mode)
        wall = time.time() - t0
        st = res.stats
        results[mode] = res
        out[mode] = {
            "triples_unmarked": st.triples_unmarked,
            "triples_total": st.triples_total,
            "rule_applications": st.rule_applications,
            "derivations": st.derivations,
            "merged": st.merged_resources,
            "rounds": st.rounds,
            "wall_s": round(wall, 3),
        }
    check_theorem1(results["REW"], results["AX"])  # paper's own validation
    ax, rew = out["AX"], out["REW"]
    out["factor"] = {
        "triples": round(ax["triples_unmarked"] / max(rew["triples_unmarked"], 1), 2),
        "rule_applications": round(
            ax["rule_applications"] / max(rew["rule_applications"], 1), 2
        ),
        "derivations": round(ax["derivations"] / max(rew["derivations"], 1), 2),
        "wall": round(ax["wall_s"] / max(rew["wall_s"], 1e-9), 2),
    }
    return out


def main(profiles=None) -> list[dict]:
    rows = []
    print(
        "dataset           mode triples(unm/tot)      rule_appl   derivations"
        "   merged  rounds   wall_s"
    )
    for name, kw in (profiles or PROFILES).items():
        r = run_one(name, kw)
        for mode in ("AX", "REW"):
            m = r[mode]
            print(
                f"{name:17s} {mode:4s} {m['triples_unmarked']:9d}/{m['triples_total']:<9d}"
                f" {m['rule_applications']:10d} {m['derivations']:12d}"
                f" {m['merged']:8d} {m['rounds']:6d} {m['wall_s']:9.3f}"
            )
        f = r["factor"]
        print(
            f"{'':17s} fact  triples x{f['triples']:<7} appl x{f['rule_applications']:<8}"
            f" deriv x{f['derivations']:<9} wall x{f['wall']}"
        )
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
