"""Query latency against a live TripleStore: idle vs during maintenance epochs.

The serving contract (docs/serving.md) answers every query from the published
epoch snapshot, so reads never block on — or observe — an in-flight
maintenance operation.  This bench quantifies that: per-query SPARQL latency
with no update in flight (**idle**) vs queries admitted between maintenance
phases while add/delete epochs run against the same store (**busy**), plus
maintenance throughput per epoch.  The epoch-consistency *correctness* of the
served answers is enforced by tests/test_serve_triple_store.py; here the
store's epoch accounting is only sanity-checked so the numbers stay honest.

The headline is the ratio ``busy_over_idle`` ~= 1: because queries read an
immutable host snapshot with a cached rho-expansion view, an epoch of
overdelete/rederive churn on the device arena costs readers nothing beyond
the scheduler tick they share the loop with.

``main(out_json=...)`` (or ``benchmarks/run.py serve``) writes the rows to
BENCH_serve.json so the serving-latency trajectory is machine-readable.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.data.generator import generate, sample_update_stream
from repro.serve.triple_store import TripleStore

# Serving-scale stand-ins for the paper's dataset regimes (smaller than the
# materialisation PROFILES: every epoch also pays a from-scratch-sized jit
# warm-up on first occurrence, and the bench runs several profiles).
SERVE_PROFILES: dict[str, dict] = {
    # chain/join-rule heavy (DBpedia-style property chains)
    "chain_like": dict(
        n_groups=20, group_size=3, n_spokes_per=2, n_plain=400,
        hierarchy_depth=2, chain_rules=True,
    ),
    # equality-dense: many/large cliques (OpenCyc-style)
    "clique_like": dict(
        n_groups=40, group_size=6, n_spokes_per=2, n_plain=200,
        hierarchy_depth=2,
    ),
    # plain-payload heavy with chains (DBpedia-style volume)
    "dbpedia_like": dict(
        n_groups=12, group_size=3, n_spokes_per=2, n_plain=1500,
        hierarchy_depth=2, chain_rules=True,
    ),
}


def _ms(xs: list[float]) -> dict:
    a = np.asarray(xs, dtype=np.float64) * 1e3
    if a.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
    return {
        "mean": round(float(a.mean()), 4),
        "p50": round(float(np.percentile(a, 50)), 4),
        "p95": round(float(np.percentile(a, 95)), 4),
    }


def run_one(
    name: str, kw: dict, n_updates: int = 4, batch: int = 16,
    n_queries: int = 24, seed: int = 0,
) -> dict:
    facts, program, dic = generate(**kw, seed=seed)
    updates = sample_update_stream(
        facts, dic, n_events=n_updates, batch=batch, seed=seed
    )
    queries = [
        payload
        for _op, payload in sample_update_stream(
            facts, dic, n_events=n_queries, batch=batch, p_query=1.0,
            seed=seed + 1,
        )
    ]

    t0 = time.perf_counter()
    store = TripleStore(facts, program, dic)
    base_s = time.perf_counter() - t0

    # -- idle: no maintenance in flight --------------------------------------
    idle_s = [store.query_now(q).wall_s for q in queries]

    # -- busy: queries admitted between the phases of running epochs ---------
    busy_s: list[float] = []
    maint_s = 0.0
    phases = 0
    qi = 0
    for op, delta in updates:
        t = store.submit_update(op, delta)
        while t.status != "done":
            s0 = time.perf_counter()
            store.step()  # one maintenance phase (query queue is empty here)
            maint_s += time.perf_counter() - s0
            phases += 1
            qt = store.query_now(queries[qi % len(queries)])
            busy_s.append(qt.wall_s)
            qi += 1
        assert t.epoch == store.epoch  # barrier accounting stays honest
    assert store.epoch == len(updates)

    idle, busy = _ms(idle_s), _ms(busy_s)
    return {
        "dataset": name,
        "facts": int(facts.shape[0]),
        "triples_served": int(store.snapshot.triples.shape[0]),
        "base_s": round(base_s, 3),
        "epochs": store.epoch,
        "maintenance_phases": phases,
        "maint_s_per_epoch": round(maint_s / max(store.epoch, 1), 4),
        "idle_query_ms": idle,
        "busy_query_ms": busy,
        "busy_over_idle": round(
            busy["mean"] / max(idle["mean"], 1e-9), 2
        ),
        "n_queries_idle": len(idle_s),
        "n_queries_busy": len(busy_s),
        "ops": [op for op, _ in updates],
    }


def main(
    profiles: dict | None = None,
    out_json: str | None = None,
    n_updates: int = 4,
    batch: int = 16,
    n_queries: int = 24,
    seed: int = 0,
) -> list[dict]:
    rows = []
    print(
        "dataset        facts  served  ep  idle q ms  busy q ms"
        "  busy/idle  maint s/ep"
    )
    for name, kw in (profiles or SERVE_PROFILES).items():
        r = run_one(
            name, kw, n_updates=n_updates, batch=batch,
            n_queries=n_queries, seed=seed,
        )
        print(
            f"{r['dataset']:14s} {r['facts']:6d} {r['triples_served']:7d}"
            f" {r['epochs']:3d} {r['idle_query_ms']['mean']:10.3f}"
            f" {r['busy_query_ms']['mean']:10.3f}"
            f"  x{r['busy_over_idle']:<8} {r['maint_s_per_epoch']:.3f}"
        )
        rows.append(r)
    if out_json:
        doc = {
            "caveat": (
                "queries are answered from the published epoch snapshot (host "
                "copy + frozen rho), so busy latency measures reads admitted "
                "between maintenance phases of the SAME single-core loop — "
                "the contract is that busy ~= idle because reads never touch "
                "the live arena; maintenance wall-clock inherits the XLA-CPU "
                "sort caveat of BENCH_incremental.json"
            ),
            "rows": rows,
        }
        with open(out_json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"[bench_serve_updates] wrote {out_json}")
    return rows


if __name__ == "__main__":
    main(out_json="BENCH_serve.json")
