"""Serving-tier latency/throughput: idle, busy, closed-loop, batched drain.

The serving contract (docs/serving.md) answers every query from the published
epoch snapshot, so reads never block on — or observe — an in-flight
maintenance operation.  This bench quantifies four things per profile:

  * **idle** — per-query latency with no update in flight;
  * **busy** — queries admitted between maintenance phases while add/delete
    epochs run against the same store (cooperative scheduler, so the
    interleaving is exact).  The headline ratio ``busy_over_idle`` ~= 1:
    snapshots are published eagerly at the epoch barrier (device-resident
    buffer swap + incremental rho refresh + host mirror), so a busy read
    costs exactly what an idle read costs — the build is charged to
    ``snapshot_build_ms`` (its own column), never to the first unlucky
    query.  The ratio is the median over per-query PAIRED ratios (each
    busy sample vs the same query idle at the same published snapshot) —
    see the attribution-discipline comment in ``run_one``;
  * **closed_loop** — a paced open workload against a ``threaded=True``
    store: queries issued at ``target_qps`` from the bench thread while the
    maintenance worker churns through update epochs concurrently; reports
    achieved qps and p50/p95/p99 latency under real concurrent update
    pressure;
  * **batched_speedup** — throughput of draining a shape-heavy *point-lookup*
    query list through the vmapped batched executor (one compiled dispatch
    per BGP shape group, :mod:`repro.sparql.batched`) over the scalar
    one-query-at-a-time host drain on the SAME snapshot.  Point lookups
    (bound subject and/or object, small answer bags) are the queries a
    serving tier batches in practice, and the regime where matching — not
    answer materialisation — is the cost: the scalar matcher scans O(N)
    triples per atom while the batched matcher binary-searches the sorted
    snapshot keys, so the gap widens with store size.  The latency sections
    above keep the generator's §5-hazard mix (scans, joins, clique
    multiplicities) — those answers are bag-materialisation-bound, which is
    shared verbatim by both matchers (``_finish``) and therefore says
    nothing about either.

Epoch-consistency *correctness* is enforced by
tests/test_serve_triple_store.py (batched == scalar == from-scratch oracle);
here the store's epoch accounting is only sanity-checked so the numbers stay
honest.  ``main(out_json=...)`` (or ``benchmarks/run.py serve``) writes the
rows to BENCH_serve.json; ``benchmarks/run.py --check`` gates the committed
rows via :func:`benchmarks.run.compare_serve`.
"""

from __future__ import annotations

import gc
import json
import time

import jax
import numpy as np

from repro.data.generator import generate, sample_update_stream
from repro.serve.triple_store import TripleStore
from repro.sparql.executor import evaluate_at

# Serving-scale stand-ins for the paper's dataset regimes (smaller than the
# materialisation PROFILES: every epoch also pays a from-scratch-sized jit
# warm-up on first occurrence, and the bench runs several profiles).
SERVE_PROFILES: dict[str, dict] = {
    # chain/join-rule heavy (DBpedia-style property chains).  n_plain keeps
    # per-query work well above the container's timer/cache-noise floor —
    # sub-100us queries make any latency *ratio* a coin flip
    "chain_like": dict(
        n_groups=20, group_size=3, n_spokes_per=2, n_plain=2000,
        hierarchy_depth=2, chain_rules=True,
    ),
    # equality-dense: many/large cliques (OpenCyc-style)
    "clique_like": dict(
        n_groups=40, group_size=6, n_spokes_per=2, n_plain=1000,
        hierarchy_depth=2,
    ),
    # plain-payload heavy with chains (DBpedia-style volume) — the
    # shape-heavy profile the batched-drain gate pins (most triples per
    # predicate, so scalar per-query joins are at their most expensive)
    "dbpedia_like": dict(
        n_groups=12, group_size=3, n_spokes_per=2, n_plain=10000,
        hierarchy_depth=2, chain_rules=True,
    ),
}


def _point_queries(facts: np.ndarray, dic, n: int, seed: int) -> list:
    """A serving-realistic point-lookup mix sampled from the explicit facts.

    Three selective single-atom shapes (three compiled shape groups): a
    subject+predicate lookup, a subject scan (out-degree-sized answer) and
    a reverse (predicate, object) lookup.  Constants are drawn from real
    triples whose subject out-degree / (p, o) fan-in is point-lookup sized
    — a hub subject or a type-like (p, o) pair has a scan-sized bag, which
    is a different workload (measured by the latency sections, not here).
    """
    from repro.sparql.algebra import Query

    rng = np.random.default_rng(seed)
    key_po = facts[:, 1].astype(np.int64) << 32 | facts[:, 2].astype(np.int64)
    _, inv, cnt = np.unique(key_po, return_inverse=True, return_counts=True)
    _, inv_s, cnt_s = np.unique(facts[:, 0], return_inverse=True,
                                return_counts=True)
    sel_po = np.flatnonzero(cnt[inv] <= 32)
    sel_s = np.flatnonzero(cnt_s[inv_s] <= 32)
    out = []
    for _ in range(n):
        kind = int(rng.integers(3))
        pool = sel_po if kind == 2 else sel_s
        if pool.shape[0] == 0:
            pool = np.arange(facts.shape[0])
        s, p, o = (int(t) for t in facts[pool[rng.integers(pool.shape[0])]])
        if kind == 0:
            q = Query([(s, p, -1)], [], [-1], False)
        elif kind == 1:
            q = Query([(s, -1, -2)], [], [-1, -2], False)
        else:
            q = Query([(-1, p, o)], [], [-1], False)
        out.append(q)
    return out


def _ms(xs: list[float]) -> dict:
    a = np.asarray(xs, dtype=np.float64) * 1e3
    if a.size == 0:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    return {
        "mean": round(float(a.mean()), 4),
        "p50": round(float(np.percentile(a, 50)), 4),
        "p95": round(float(np.percentile(a, 95)), 4),
        "p99": round(float(np.percentile(a, 99)), 4),
    }


def _closed_loop(
    facts, program, dic, queries, updates, target_qps: float, n_cl: int,
) -> dict:
    """Paced queries from this thread vs the maintenance worker thread.

    A fresh ``threaded=True`` store (the cooperative store's interleaving
    is hand-scheduled; this one races for real).  Updates are fed in evenly
    across the query window so the worker stays busy under the pacing.
    """
    store = TripleStore(facts, program, dic, threaded=True)
    try:
        for q in queries:  # warm the compiled matchers off the clock
            store.submit_query(q)
        store.drain()
        period = 1.0 / target_qps
        every = max(n_cl // max(len(updates), 1), 1)
        lat: list[float] = []
        tickets = []
        next_t = t_start = time.perf_counter()
        ui = 0
        for i in range(n_cl):
            if i % every == 0 and ui < len(updates):
                op, delta = updates[ui]
                tickets.append(store.submit_update(op, delta))
                ui += 1
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            next_t += period
            lat.append(store.query_now(queries[i % len(queries)]).wall_s)
        dur = time.perf_counter() - t_start
        busy_n = sum(1 for x in lat if x is not None)
        store.drain()
        assert all(t.status == "done" for t in tickets)
        return {
            "target_qps": round(target_qps, 1),
            "achieved_qps": round(busy_n / max(dur, 1e-9), 1),
            "n_queries": n_cl,
            "updates_submitted": len(updates),
            "epochs_completed": store.epoch,
            "latency_ms": _ms(lat),
        }
    finally:
        store.close()


def run_one(
    name: str, kw: dict, n_updates: int = 4, batch: int = 16,
    n_queries: int = 24, seed: int = 0, target_qps: float = 150.0,
    closed_loop_queries: int | None = None, drain_list_len: int = 128,
) -> dict:
    facts, program, dic = generate(**kw, seed=seed)
    updates = sample_update_stream(
        facts, dic, n_events=n_updates, batch=batch, seed=seed
    )
    queries = [
        payload
        for _op, payload in sample_update_stream(
            facts, dic, n_events=n_queries, batch=batch, p_query=1.0,
            seed=seed + 1,
        )
    ]

    t0 = time.perf_counter()
    store = TripleStore(facts, program, dic)
    base_s = time.perf_counter() - t0

    # warm the query paths (scalar + every batched shape group) so the
    # latency sections below measure steady-state dispatch, not compiles
    for q in queries:
        store.submit_query(q)
    store.drain()

    # -- idle: no maintenance in flight --------------------------------------
    idle_s = [store.query_now(q).wall_s for q in queries]

    # -- busy: queries admitted between the phases of running epochs ---------
    # The attribution discipline, each piece of which changes the answer:
    #   * paired baseline — per-query cost spans orders of magnitude across
    #     the hazard mix AND grows as add epochs grow the store, so each
    #     busy sample is compared against the SAME query measured idle at
    #     the SAME published snapshot (the pre-update baseline pass), never
    #     against the mix-wide mean;
    #   * device sync — step() returns at XLA *dispatch*; the async device
    #     tail is maintenance cost and is drained (and billed to maint_s)
    #     before the query clock starts;
    #   * gc in the maintenance window — a deferred collection otherwise
    #     lands on whichever query allocates next (observed: a ~60ms pause
    #     billed to a 1ms read);
    #   * a short burst per phase — a serving tier answers streams between
    #     phases; the first read after device work pays the cold-cache
    #     toll, the burst is what a client actually sees;
    #   * median of paired ratios — robust to container timer jitter, which
    #     dominates any sum at sub-millisecond latencies.
    busy_s: list[float] = []
    idle_extra: list[float] = []
    ratios: list[float] = []
    maint_s = 0.0
    phases = 0
    qi = 0
    for op, delta in updates:
        idle_now = [store.query_now(q).wall_s for q in queries]
        idle_extra.extend(idle_now)
        t = store.submit_update(op, delta)
        while t.status != "done":
            s0 = time.perf_counter()
            store.step()  # one maintenance phase (query queue is empty here)
            st = store.state
            jax.block_until_ready(
                [st.spo, st.epoch, st.marked, st.tomb, st.n_used,
                 st.rep, st.sort_perm, st.sorted_keys]
            )
            gc.collect()
            maint_s += time.perf_counter() - s0
            phases += 1
            for _ in range(4):
                qt = store.query_now(queries[qi % len(queries)])
                busy_s.append(qt.wall_s)
                ratios.append(
                    qt.wall_s / max(idle_now[qi % len(queries)], 1e-9)
                )
                qi += 1
        assert t.epoch == store.epoch  # barrier accounting stays honest
    assert store.epoch == len(updates)

    # -- batched vs scalar drain throughput at the final epoch ---------------
    snap = store.snapshot
    qlist = _point_queries(facts, dic, drain_list_len, seed + 2)
    bx = store._batched
    bx.run(qlist, snap, dic)  # warm any residual compile at this batch shape
    tb, ts = [], []
    for _ in range(3):  # medians: one drain is jitter-prone at these sizes
        s0 = time.perf_counter()
        bx.run(qlist, snap, dic)
        tb.append(time.perf_counter() - s0)
        s0 = time.perf_counter()
        for q in qlist:
            evaluate_at(q, snap, dic)
        ts.append(time.perf_counter() - s0)
    t_batched = sorted(tb)[1]
    t_scalar = sorted(ts)[1]
    batched_speedup = t_scalar / max(t_batched, 1e-9)

    # -- closed-loop load against a threaded store ---------------------------
    cl_updates = sample_update_stream(
        facts, dic, n_events=n_updates, batch=batch, seed=seed + 2
    )
    closed = _closed_loop(
        facts, program, dic, queries, cl_updates, target_qps,
        closed_loop_queries or max(4 * n_queries, 96),
    )

    audit_problems = store.audit()
    idle, busy = _ms(idle_s + idle_extra), _ms(busy_s)
    return {
        "dataset": name,
        "facts": int(facts.shape[0]),
        "triples_served": int(store.snapshot.triples.shape[0]),
        "base_s": round(base_s, 3),
        "epochs": store.epoch,
        "maintenance_phases": phases,
        "maint_s_per_epoch": round(maint_s / max(store.epoch, 1), 4),
        "idle_query_ms": idle,
        "busy_query_ms": busy,
        "busy_over_idle": round(float(np.median(ratios)), 2) if ratios
        else None,
        # the publication cost, as its own column: construction first, then
        # one entry per epoch barrier (the attribution fix — reads above
        # never pay this)
        "snapshot_build_ms": _ms([x / 1e3 for x in store.publish_ms]),
        "batched_speedup": round(batched_speedup, 2),
        "batched_drain_qps": round(len(qlist) / max(t_batched, 1e-9), 1),
        "scalar_drain_qps": round(len(qlist) / max(t_scalar, 1e-9), 1),
        "batched_stats": dict(bx.stats),
        "closed_loop": closed,
        "audit_problems": audit_problems,
        "n_queries_idle": len(idle_s) + len(idle_extra),
        "n_queries_busy": len(busy_s),
        "ops": [op for op, _ in updates],
    }


def main(
    profiles: dict | None = None,
    out_json: str | None = None,
    n_updates: int = 4,
    batch: int = 16,
    n_queries: int = 24,
    seed: int = 0,
    target_qps: float = 150.0,
) -> list[dict]:
    rows = []
    print(
        "dataset        facts  served  ep  idle q ms  busy q ms  busy/idle"
        "  batchx  cl p95 ms"
    )
    for name, kw in (profiles or SERVE_PROFILES).items():
        r = run_one(
            name, kw, n_updates=n_updates, batch=batch,
            n_queries=n_queries, seed=seed, target_qps=target_qps,
        )
        print(
            f"{r['dataset']:14s} {r['facts']:6d} {r['triples_served']:7d}"
            f" {r['epochs']:3d} {r['idle_query_ms']['mean']:10.3f}"
            f" {r['busy_query_ms']['mean']:10.3f}"
            f"  x{r['busy_over_idle']:<7} x{r['batched_speedup']:<5}"
            f" {r['closed_loop']['latency_ms']['p95']:9.3f}"
        )
        rows.append(r)
    if out_json:
        doc = {
            "caveat": (
                "queries are answered from device-resident double-buffered "
                "epoch snapshots published eagerly at each maintenance "
                "barrier; busy ~= idle because readers never touch the live "
                "arena and never pay the snapshot build (snapshot_build_ms "
                "is its own column).  closed_loop paces queries from the "
                "bench thread at target_qps against a threaded store whose "
                "maintenance worker runs concurrent epochs; batched_speedup "
                "is the vmapped shape-grouped drain vs the scalar host "
                "drain on the same snapshot.  Maintenance wall-clock "
                "inherits the XLA-CPU sort caveat of BENCH_incremental.json"
            ),
            "rows": rows,
        }
        with open(out_json, "w") as fh:
            json.dump(doc, fh, indent=2)
        print(f"[bench_serve_updates] wrote {out_json}")
    return rows


if __name__ == "__main__":
    main(out_json="BENCH_serve.json")
