"""Pallas kernel microbench: interpret-mode wall time vs the jnp oracle.

CPU interpret-mode timings do NOT reflect TPU performance (each grid step
runs the kernel body in Python-driven XLA); the numbers here are a
correctness + plumbing check.  The TPU-relevant analysis of these kernels is
the BlockSpec/VMEM sizing in each kernel file and the §Roofline terms.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, repeats=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / repeats * 1e3


def main() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # pointer_jump: rho-closure (interpret mode => small shapes; TPU shapes in kernel docstrings)
    table = np.arange(1 << 13, dtype=np.int32)
    table[1:] = rng.integers(0, np.arange(1, 1 << 13))  # random forest
    idx = rng.integers(0, 1 << 13, (1 << 12,)).astype(np.int32)
    rows.append({
        "kernel": "pointer_jump",
        "pallas_ms": _time(lambda a, b: ops.pointer_jump(a, b, interpret=True), idx, table),
        "ref_ms": _time(ref.pointer_jump_ref, idx, table),
    })

    # rewrite_triples: 64k-triple arena sweep
    spo = rng.integers(0, 1 << 13, (1 << 13, 3)).astype(np.int32)
    rho = np.arange(1 << 13, dtype=np.int32)
    rho[rng.integers(0, 1 << 13, 1 << 10)] = 0
    rows.append({
        "kernel": "rewrite_triples",
        "pallas_ms": _time(lambda a, b: ops.rewrite_triples(a, b, interpret=True), spo, rho),
        "ref_ms": _time(ref.rewrite_triples_ref, spo, rho),
    })

    # embedding_bag: 4k bags x 16 ids from a 1M x 64 table
    table_f = rng.normal(size=(1 << 14, 64)).astype(np.float32)
    ids = rng.integers(0, 1 << 14, (1 << 10, 16)).astype(np.int32)
    rows.append({
        "kernel": "embedding_bag",
        "pallas_ms": _time(lambda a, b: ops.embedding_bag(a, b, interpret=True), ids, table_f),
        "ref_ms": _time(ref.embedding_bag_ref, ids, table_f),
    })

    # fm_interact: 8k x 39 x 16 sum-square interaction
    emb = rng.normal(size=(1 << 10, 39, 16)).astype(np.float32)
    rows.append({
        "kernel": "fm_interact",
        "pallas_ms": _time(lambda a: ops.fm_interact(a, interpret=True), emb),
        "ref_ms": _time(ref.fm_interact_ref, emb),
    })

    print("kernel            pallas(interp)_ms     ref_ms")
    for r in rows:
        print(f"{r['kernel']:17s} {r['pallas_ms']:14.2f} {r['ref_ms']:10.2f}")
    return rows


if __name__ == "__main__":
    main()
