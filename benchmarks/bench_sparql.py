"""§5 analogue: SPARQL on the succinct store T vs the expansion T^rho.

The paper's §5 argument: evaluating rho(Q) over T (with the corrected
projection/builtin semantics) is both CORRECT and FASTER than evaluating Q
over the expansion — the joins touch fewer triples.  This bench measures
both on the equality-dense profile and verifies answer equality.
"""

from __future__ import annotations

import time
from collections import Counter

import numpy as np

from repro.core.materialise import materialise
from repro.data.generator import generate, PROFILES
from repro.sparql import Query, evaluate


def expansion_triples(res) -> np.ndarray:
    """Materialise T^rho explicitly (what a no-rewriting store would hold)."""
    from repro.core.materialise import expand

    return np.asarray(sorted(expand(res.triples(), res.rep)), dtype=np.int32)


def bench(profile: str = "opencyc_like", repeats: int = 5) -> dict:
    facts, prog, dic = generate(**PROFILES[profile])
    res = materialise(facts, prog, dic.n_resources, mode="REW")
    t_small = res.triples()
    t_full = expansion_triples(res)
    ident = np.arange(res.rep.shape[0], dtype=res.rep.dtype)

    queries = {
        "spoke_pairs": "SELECT ?x WHERE { (?x, :spoke, ?y) }",
        "typed_spokes": "SELECT ?x ?c WHERE { (?x, :spoke, ?y) . (?y, rdf:type, ?c) }",
        "two_hop": "SELECT ?x WHERE { (?x, :spoke, ?y) . (?z, :spoke, ?y) }",
    }
    out = {"profile": profile, "triples_small": int(t_small.shape[0]),
           "triples_full": int(t_full.shape[0])}
    for name, text in queries.items():
        q = Query.parse(text, dic)
        t0 = time.time()
        for _ in range(repeats):
            a_small = evaluate(q, t_small, res.rep, dic)
        small_s = (time.time() - t0) / repeats
        t0 = time.time()
        for _ in range(repeats):
            a_full = evaluate(q, t_full, ident, dic)
        full_s = (time.time() - t0) / repeats
        assert a_small == a_full, f"{name}: rewriting changed answers!"
        out[name] = {
            "rewritten_ms": round(small_s * 1e3, 2),
            "expanded_ms": round(full_s * 1e3, 2),
            "speedup": round(full_s / max(small_s, 1e-9), 2),
            "n_answers": sum(a_small.values()),
        }
    return out


def main() -> list[dict]:
    rows = []
    print("profile        query            rewritten_ms  expanded_ms  speedup  answers")
    for profile in ("opencyc_like", "claros_like"):
        r = bench(profile)
        for qname in ("spoke_pairs", "typed_spokes", "two_hop"):
            m = r[qname]
            print(
                f"{profile:14s} {qname:16s} {m['rewritten_ms']:12.2f}"
                f" {m['expanded_ms']:12.2f} {m['speedup']:8.2f} {m['n_answers']:8d}"
            )
        rows.append(r)
    return rows


if __name__ == "__main__":
    main()
